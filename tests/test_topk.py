"""Ranked retrieval's differential gate (DESIGN.md §9).

BM25 top-k with block-max page pruning must return EXACTLY the
brute-force oracle's answer — float32-identical scores AND tie-broken
(score desc, doc asc) order — on every engine configuration (host /
jnp flat / jnp paged / pallas interpret / 1-device-mesh shard_map),
pruned and exhaustive, serial and through the coalescing scheduler.

Plus the pins: the 128-symbol block-max directory (partition + upper
bounds + page-straddling lists), pruned-vs-exhaustive page accounting
with actual skips on a crafted corpus, deterministic tie-breaking,
degenerate k / OOV bags, result-cache keying across scoring modes, and
ranked-round coalescing.
"""

import os

import numpy as np
import pytest

from strategies import adversarial_lists

from repro.core.jax_index import build_score_index
from repro.core.repair import repair_compress
from repro.engine import HostEngine, JnpEngine, PallasEngine
from repro.query import QueryExecutor, rank_oracle, search_topk
from repro.serve.scheduler import QueryScheduler

SEED = int(os.environ.get("REPRO_BENCH_SEED", "0"))
ENGINE_CONFIGS = ("host", "jnp", "jnp_paged", "pallas")


@pytest.fixture(scope="module")
def rlists():
    # module-own rng: corpus identical no matter what ran before (the
    # same isolation convention as the scheduler gate)
    return adversarial_lists(np.random.default_rng(SEED + 204),
                             universe=700, n_random=8, max_len=70)


@pytest.fixture(scope="module")
def rres(rlists):
    return repair_compress(rlists)


def _make_engine(name, res):
    if name == "host":
        return HostEngine(res)
    if name == "jnp":
        return JnpEngine(res, max_short_len=64)
    if name == "jnp_paged":
        return JnpEngine(res, max_short_len=64, paged=True, page_size=128)
    if name == "pallas":
        return PallasEngine(res, max_short_len=64, interpret=True,
                            page_size=128)
    raise ValueError(name)


@pytest.fixture(scope="module")
def rengines(rres):
    return {name: _make_engine(name, rres) for name in ENGINE_CONFIGS}


def _bags(num_lists, n, seed_off=0):
    """Seeded term bags: duplicates and out-of-vocabulary ids included —
    the driver must dedupe and drop them."""
    rng = np.random.default_rng(SEED + 31 + seed_off)
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 5))
        bag = [int(t) for t in rng.integers(0, num_lists, size=k)]
        if rng.random() < 0.3:
            bag.append(bag[0])                       # duplicate term
        if rng.random() < 0.3:
            bag.append(int(rng.choice([-1, num_lists + 2])))   # OOV
        out.append(bag)
    return out


# -- the differential gate ---------------------------------------------------

@pytest.mark.parametrize("ename", ENGINE_CONFIGS)
def test_topk_matches_oracle(rlists, rres, rengines, ename):
    """Exact scores and exact order vs the brute-force BM25 oracle,
    pruned AND exhaustive, across k."""
    eng = rengines[ename]
    n = 6 if ename == "pallas" else 12     # interpret mode is slow
    for i, bag in enumerate(_bags(len(rlists), n)):
        k = (1, 3, 10)[i % 3]
        want_d, want_s = rank_oracle(rlists, rres.universe, bag, k)
        for prune in (True, False):
            got = search_topk(eng, bag, k, prune=prune)
            np.testing.assert_array_equal(got.docs, want_d,
                                          err_msg=f"{ename} bag={bag} k={k}")
            np.testing.assert_array_equal(got.scores, want_s)


def test_topk_sharded_dispatch(rlists, rres):
    """The membership probes of the scoring rounds ride the shard_map
    dispatch when the engine carries a mesh (1-device mesh: same math,
    sharded code path)."""
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    eng = JnpEngine(rres, max_short_len=64, mesh=mesh)
    for bag in _bags(len(rlists), 5, seed_off=1):
        want_d, want_s = rank_oracle(rlists, rres.universe, bag, 5)
        got = search_topk(eng, bag, 5)
        np.testing.assert_array_equal(got.docs, want_d)
        np.testing.assert_array_equal(got.scores, want_s)


def test_topk_through_scheduler(rlists, rres, rengines):
    """Scheduler-coalesced ranked execution == the serial path, and the
    ranked rounds of concurrent queries actually merge."""
    eng = rengines["host"]
    bags = _bags(len(rlists), 10, seed_off=2)
    serial = [search_topk(eng, bag, 10) for bag in bags]
    sch = QueryScheduler(eng, batch_window=8, result_cache_size=0)
    outs = sch.search_topk_many(bags, 10)
    for want, got in zip(serial, outs):
        np.testing.assert_array_equal(got.docs, want.docs)
        np.testing.assert_array_equal(got.scores, want.scores)
        assert got.pages_scored == want.pages_scored
        assert got.pages_skipped == want.pages_skipped
    st = sch.stats()
    assert st["coalescing_factor"] > 1.0, st
    assert st["pages_scored"] == sum(r.pages_scored for r in serial)


def test_topk_mixed_with_boolean_traffic(rlists, rres, rengines):
    """Ranked and boolean queries interleave on one scheduler; both
    stay exact."""
    from repro.query import naive_eval
    eng = rengines["host"]
    sch = QueryScheduler(eng, batch_window=8)
    bag = [0, 2, 5]
    bool_q = "(0 AND 2) OR 5"
    qid_r = sch.submit_topk(bag, 10)
    qid_b = sch.submit(bool_q)
    sch.drain()
    want_d, want_s = rank_oracle(rlists, rres.universe, bag, 10)
    got_r = sch.take(qid_r)
    np.testing.assert_array_equal(got_r.docs, want_d)
    np.testing.assert_array_equal(got_r.scores, want_s)
    node = QueryExecutor(eng).plan(bool_q).node
    np.testing.assert_array_equal(sch.take(qid_b),
                                  naive_eval(node, rlists, rres.universe))


def test_executor_topk_entrypoint(rlists, rres, rengines):
    """QueryExecutor.topk accepts query strings — the term bag is the
    string's terms."""
    qx = QueryExecutor(rengines["host"])
    got = qx.topk("0 AND 3", 7)
    want_d, want_s = rank_oracle(rlists, rres.universe, [0, 3], 7)
    np.testing.assert_array_equal(got.docs, want_d)
    np.testing.assert_array_equal(got.scores, want_s)


# -- behaviour pins ----------------------------------------------------------

def test_topk_edge_cases(rlists, rres, rengines):
    eng = rengines["host"]
    # k = 0 and OOV-only bags: empty result, nothing scored
    for bag, k in (([0, 1], 0), ([-1, len(rlists) + 5], 10)):
        got = search_topk(eng, bag, k)
        assert got.docs.size == 0 and got.scores.size == 0
        assert got.pages_scored == 0 and got.pages_skipped == 0
    # k beyond the matching-doc count returns every matching doc
    bag = [8]                        # the singleton list
    got = search_topk(eng, bag, 50)
    want_d, want_s = rank_oracle(rlists, rres.universe, bag, 50)
    assert got.docs.size == rlists[8].size == 1
    np.testing.assert_array_equal(got.docs, want_d)
    np.testing.assert_array_equal(got.scores, want_s)
    # duplicate terms == the deduped bag
    a = search_topk(eng, [0, 0, 1, 1], 5)
    b = search_topk(eng, [0, 1], 5)
    np.testing.assert_array_equal(a.docs, b.docs)
    np.testing.assert_array_equal(a.scores, b.scores)


def test_topk_tie_break_is_doc_ascending():
    """Docs with bit-identical scores rank by ascending doc id — pinned
    on a corpus where EVERY doc ties (same doc length, same membership)."""
    lists = [np.arange(20, dtype=np.int64), np.arange(20, dtype=np.int64)]
    res = repair_compress(lists)
    eng = HostEngine(res)
    got = search_topk(eng, [0, 1], 8)
    np.testing.assert_array_equal(got.docs, np.arange(8))
    assert np.unique(got.scores).size == 1
    want_d, want_s = rank_oracle(lists, res.universe, [0, 1], 8)
    np.testing.assert_array_equal(got.docs, want_d)
    np.testing.assert_array_equal(got.scores, want_s)


def test_blockmax_directory_page128(rres, rlists):
    """The 128-symbol directory partitions every list exactly: entry
    counts sum to list lengths, per-entry slices tile the decode, the
    block maxima really bound their slices, and at this page size some
    list MUST straddle a page boundary (the stream is contiguous)."""
    si = build_score_index(rres, page_size=128)
    assert si.page_size == 128
    straddlers = 0
    for t, lst in enumerate(rlists):
        lo, hi = int(si.page_off[t]), int(si.page_off[t + 1])
        ents = np.arange(lo, hi)
        assert int(si.pg_count[ents].sum()) == lst.size
        straddlers += ents.size > 1
        pieces = []
        for e in ents:
            elo, cnt = int(si.pg_elem_lo[e]), int(si.pg_count[e])
            sl = lst[elo:elo + cnt]
            pieces.append(sl)
            contrib = si.idf[t] * si.doc_w[sl]
            assert np.float32(contrib.max()) == si.pg_ub[e]
            assert np.float32(si.doc_w[sl].max()) == si.pg_wmax[e]
            assert int(sl[-1]) == int(si.pg_last[e])
        np.testing.assert_array_equal(np.concatenate(pieces), lst)
    assert straddlers > 0, "fixture must exercise page-straddling lists"


def _skip_corpus():
    """A corpus engineered so block-max pruning MUST skip: a long,
    incompressible common list B spanning several 128-symbol pages, and
    a rare list A = B's 40 smallest docs.  Top-k docs match both terms,
    so θ clears the bound of every B page beyond A's doc range (their
    doc-aligned rest is 0)."""
    rng = np.random.default_rng(SEED + 77)
    B = np.unique(rng.choice(4000, size=1400, replace=False))
    A = B[:40]
    fillers = [np.unique(rng.choice(4000, size=60, replace=False))
               for _ in range(6)]
    return [A, B] + fillers


@pytest.mark.parametrize("ename", ENGINE_CONFIGS)
def test_pruning_skips_and_matches_exhaustive(ename):
    """pages(pruned) + pages(skipped) == pages(exhaustive), skips > 0,
    and the pruned answer is still oracle-exact — on every backend, off
    one SHARED directory so the admission decisions are identical."""
    lists = _skip_corpus()
    res = repair_compress(lists)
    si = build_score_index(res, page_size=128)
    eng = _make_engine(ename, res)
    if ename in ("host", "jnp"):
        eng.score_page_size = 128
    eng.set_score_index(si)
    bag = [0, 1]
    want_d, want_s = rank_oracle(lists, res.universe, bag, 10)
    got = search_topk(eng, bag, 10)
    exh = search_topk(eng, bag, 10, prune=False)
    for r in (got, exh):
        np.testing.assert_array_equal(r.docs, want_d)
        np.testing.assert_array_equal(r.scores, want_s)
    assert got.pages_skipped > 0, "crafted corpus must produce skips"
    assert got.pages_scored + got.pages_skipped == exh.pages_scored
    assert exh.pages_skipped == 0


def test_device_page_decode_matches_host():
    """decode_page_batch is bit-identical host vs jnp-windowed vs the
    pallas kernel (tile-guarded rows included) over EVERY directory
    entry at page 128."""
    lists = _skip_corpus()
    res = repair_compress(lists)
    si = build_score_index(res, page_size=128)
    host = _make_engine("host", res)
    host.score_page_size = 128
    host.set_score_index(si)
    engines = [_make_engine("jnp_paged", res), _make_engine("pallas", res)]
    for eng in engines:
        eng.set_score_index(si)
    all_entries = np.arange(si.pg_list.size, dtype=np.int32)
    want = host.decode_page_batch(all_entries)
    for eng in engines:
        got = eng.decode_page_batch(all_entries)
        assert got.shape[0] == want.shape[0]
        w = min(got.shape[1], want.shape[1])
        np.testing.assert_array_equal(got[:, :w], want[:, :w],
                                      err_msg=eng.name)
        # wider padding (if any) is all INT_INF
        assert (got[:, w:] == np.iinfo(np.int32).max).all()


def test_score_batch_matches_oracle(rlists, rres, rengines):
    """engine.score_batch == the oracle's scores for any doc subset,
    including docs matching no term (score 0)."""
    bag = [0, 1, 4]
    want_d, want_s = rank_oracle(rlists, rres.universe, bag,
                                 rres.universe)
    lookup = dict(zip(want_d.tolist(), want_s.tolist()))
    rng = np.random.default_rng(SEED + 5)
    docs = np.unique(rng.integers(0, rres.universe, size=40))
    want = np.asarray([lookup.get(int(d), 0.0) for d in docs], np.float32)
    for ename in ENGINE_CONFIGS:
        got = rengines[ename].score_batch(docs, bag)
        np.testing.assert_array_equal(got, want, err_msg=ename)


def test_result_cache_keying_across_modes(rlists, rres):
    """Boolean and ranked results never collide in the result cache, and
    ranked entries are keyed by (terms, k, prune)."""
    from repro.serve.query_serve import QueryServer
    srv = QueryServer(rres, engine="host")
    bool_out = srv.search("0 AND 1")
    r10 = srv.search_topk("0 AND 1", 10)
    r3 = srv.search_topk("0 AND 1", 3)
    assert isinstance(bool_out, np.ndarray)
    assert r10.docs.size >= r3.docs.size
    np.testing.assert_array_equal(r3.docs, r10.docs[:r3.docs.size])
    h0 = srv.serve_stats()["result_cache"]["hits"]
    again = srv.search_topk("0 AND 1", 10)          # cache hit
    assert srv.serve_stats()["result_cache"]["hits"] == h0 + 1
    np.testing.assert_array_equal(again.docs, r10.docs)
    np.testing.assert_array_equal(again.scores, r10.scores)
    # the cached copy is immutable; the handed-out copy is independent
    again.docs = np.array([])       # mutate the returned object freely
    fresh = srv.search_topk("0 AND 1", 10)
    np.testing.assert_array_equal(fresh.docs, r10.docs)
