"""Streaming ingestion differential gate (DESIGN.md §12).

The ROADMAP's gate, held at every step: **interleaved insert/search must
be bit-identical to rebuild-from-scratch** — boolean answers equal the
numpy set oracle over the full current corpus, ranked top-k answers equal
``rank_oracle`` exactly (scores AND order), on every engine configuration
(host / jnp flat / jnp paged / pallas interpret) and on a 1-device-mesh
shard_map dispatch, with flushes and background compactions landing
between the checks.

Plus the crash/restart semantics of the satellite checklist: the delta
tier replays from the one-integer mutation-log cursor, a killed flush
leaves the previous segment set serving, and compaction replay converges
to the same segment layout (idempotence).
"""

import os

import numpy as np
import pytest

from repro.build import make_builder
from repro.data.pipeline import PostingsSource
from repro.engine import make_engine
from repro.query import naive_eval
from repro.query.ast import And, Not, Or, Term
from repro.query.parser import parse
from repro.query.steps import ProbeRound, ScoreRound
from repro.query.topk import rank_oracle
from repro.segment import DELTA_BUDGET_ENV, SegmentedIndex
from repro.serve.query_serve import QueryServer

SEED = int(os.environ.get("REPRO_BENCH_SEED", "0"))
VOCAB = 64

ENGINE_CONFIGS = {
    "host": {},
    "jnp": {"max_short_len": 64},
    "jnp_paged": {"max_short_len": 64, "paged": True, "page_size": 128},
    "pallas": {"max_short_len": 64, "interpret": True},
}


def _corpus(n, seed=SEED):
    """Coverage corpus: doc 0 holds every term, so global term id ==
    dense list index and the rebuilt-from-scratch universe equals the
    doc count on both sides of the gate."""
    src = PostingsSource(base_docs=16, growth_docs=8, vocab=VOCAB,
                        mean_doc_len=12, seed=seed + 17)
    return [np.arange(VOCAB, dtype=np.int64)] + \
        [src.doc_terms(d) for d in range(n - 1)]


def _invert(docs):
    inv = {}
    for d, terms in enumerate(docs):
        for t in terms.tolist():
            inv.setdefault(int(t), []).append(d)
    return [np.asarray(inv[t], np.int64) for t in sorted(inv)]


def _queries(rng):
    a, b, c = (int(t) for t in rng.choice(VOCAB, 3, replace=False))
    return [And((Term(a), Term(b))),
            Or((Term(a), Not(Term(c)))),
            And((Term(a), Not(And((Term(b), Term(c))))))]


def _engine_name(name):
    return "jnp" if name == "jnp_paged" else name


def _server(res, name, **extra):
    kw = dict(ENGINE_CONFIGS[name])
    kw.pop("max_short_len", None)
    return QueryServer(res, max_short_len=64, engine=_engine_name(name),
                       **kw, **extra)


# -- the interleaved ≡ rebuild gate, all engines -----------------------------

@pytest.mark.parametrize("name", sorted(ENGINE_CONFIGS))
def test_interleaved_equals_rebuild_every_step(name):
    docs = _corpus(56)
    bld = make_builder("host")
    srv = _server(res=bld.build_grammar(_invert(docs[:24])), name=name)
    srv.enable_ingest(delta_budget=6, compact_fanout=2)
    rng = np.random.default_rng(SEED + 1)
    for i, d in enumerate(docs[24:]):
        srv.insert(d)
        cur = docs[:25 + i]
        lists, n = _invert(cur), len(cur)
        qs = _queries(rng)
        for q, got in zip(qs, srv.search_many(qs)):
            np.testing.assert_array_equal(got, naive_eval(q, lists, n))
        ts = sorted(int(t) for t in rng.choice(VOCAB, 4, replace=False))
        rr = srv.search_topk(ts, 10)
        od, osc = rank_oracle(lists, n, ts, 10)
        np.testing.assert_array_equal(rr.docs, od)
        np.testing.assert_array_equal(rr.scores, osc)
    st = srv.serve_stats()
    assert st["flushes"] >= 3 and st["segments"] >= 2, st
    assert st["compactions"] >= 1, st       # background merges ran
    assert st["ingested_docs"] == 32, st


def test_interleaved_equals_rebuild_sharded():
    """Same gate through the 1-device-mesh shard_map dispatch."""
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    docs = _corpus(40)
    bld = make_builder("host")
    srv = _server(res=bld.build_grammar(_invert(docs[:28])), name="jnp",
                  mesh=mesh)
    srv.enable_ingest(delta_budget=5, compact_fanout=2)
    rng = np.random.default_rng(SEED + 2)
    for i, d in enumerate(docs[28:]):
        srv.insert(d)
        cur = docs[:29 + i]
        lists, n = _invert(cur), len(cur)
        qs = _queries(rng)
        for q, got in zip(qs, srv.search_many(qs)):
            np.testing.assert_array_equal(got, naive_eval(q, lists, n))
        ts = sorted(int(t) for t in rng.choice(VOCAB, 3, replace=False))
        rr = srv.search_topk(ts, 8)
        od, osc = rank_oracle(lists, n, ts, 8)
        np.testing.assert_array_equal(rr.docs, od)
        np.testing.assert_array_equal(rr.scores, osc)


def test_result_cache_correct_across_inserts():
    """Result keys fold in the content epoch: an insert must invalidate,
    a flush/compaction (content-preserving) must NOT."""
    docs = _corpus(32)
    bld = make_builder("host")
    srv = _server(res=bld.build_grammar(_invert(docs[:24])), name="host")
    srv.enable_ingest(delta_budget=100, compact_fanout=2)
    q = "(0 AND 1) OR NOT 2"
    node = parse(q, None)
    srv.insert(docs[24])
    first = srv.search(q)
    h0 = srv.serve_stats()["result_cache"]["hits"]
    np.testing.assert_array_equal(srv.search(q), first)
    assert srv.serve_stats()["result_cache"]["hits"] == h0 + 1
    # flush reorganizes without changing content: still a cache hit
    srv.flush()
    np.testing.assert_array_equal(srv.search(q), first)
    assert srv.serve_stats()["result_cache"]["hits"] == h0 + 2
    # an insert changes content: the stale entry must not serve
    srv.insert(docs[25])
    lists, n = _invert(docs[:26]), 26
    np.testing.assert_array_equal(srv.search(q),
                                  naive_eval(node, lists, n))


# -- crash/restart semantics -------------------------------------------------

def _drive(machine):
    try:
        step = next(machine)
        while True:
            if isinstance(step, ProbeRound):
                r = step.engine.dispatch_round(step.list_ids, step.xs,
                                               step.algo)
            elif isinstance(step, ScoreRound):
                r = step.engine.dispatch_score_round(step.entries)
            else:
                r = step.run()
            step = machine.send(r)
    except StopIteration as s:
        return s.value


def _manager(docs, n_base, **kw):
    bld = make_builder("host")
    res = bld.build_grammar(_invert(docs[:n_base]))
    eng = make_engine("host", res)
    return SegmentedIndex(res, eng, lambda r: make_engine("host", r),
                          builder="host", **kw)


def test_delta_replays_from_cursor():
    """The delta tier is a pure function of the mutation log past the
    one-integer cursor (the ``PipelineCursor`` contract): replaying it
    into a fresh manager reproduces the answers exactly."""
    docs = _corpus(40)
    seg = _manager(docs, 20, delta_budget=8)
    for d in docs[20:]:
        seg.insert(d)
    assert seg.delta_docs > 0          # a live (unflushed) tail exists
    # "restart": fresh manager over the same base, replay log[cursor0:]
    replay = _manager(docs, 20, delta_budget=10_000)   # no auto-flush
    for i in range(len(docs) - 20):
        replay.insert(seg.log_entry(i))
    assert replay.delta_docs == len(docs) - 20
    rng = np.random.default_rng(SEED + 3)
    lists, n = _invert(docs), len(docs)
    for q in _queries(rng):
        want = naive_eval(q, lists, n)
        np.testing.assert_array_equal(_drive(seg.lower_bool(q)), want)
        np.testing.assert_array_equal(_drive(replay.lower_bool(q)), want)
    ts = sorted(int(t) for t in rng.choice(VOCAB, 4, replace=False))
    a, b = _drive(seg.lower_topk(ts, 10)), _drive(replay.lower_topk(ts, 10))
    np.testing.assert_array_equal(a.docs, b.docs)
    np.testing.assert_array_equal(a.scores, b.scores)


class _KilledFlush(RuntimeError):
    pass


def test_flush_is_atomic_under_crash():
    """A flush killed mid-build (builder raises) must leave the previous
    (segments, cursor) pair serving — nothing half-committed — and a
    retry must succeed from the intact log."""
    docs = _corpus(36)
    seg = _manager(docs, 20, delta_budget=10_000)
    for d in docs[20:]:
        seg.insert(d)
    segs0, cursor0, delta0 = seg.segments, seg.cursor, seg.delta_docs

    class _Bomb:
        def build_grammar(self, lists):
            raise _KilledFlush("killed mid-flush")
    good_builder, seg._builder = seg._builder, _Bomb()
    with pytest.raises(_KilledFlush):
        seg.flush()
    # previous state still serving, bit-for-bit
    assert seg.segments is segs0
    assert seg.cursor == cursor0 and seg.delta_docs == delta0
    rng = np.random.default_rng(SEED + 4)
    lists, n = _invert(docs), len(docs)
    for q in _queries(rng):
        np.testing.assert_array_equal(_drive(seg.lower_bool(q)),
                                      naive_eval(q, lists, n))
    # restart/retry with the real builder: the intact log flushes fully
    seg._builder = good_builder
    assert seg.flush() is not None
    assert seg.delta_docs == 0
    for q in _queries(np.random.default_rng(SEED + 4)):
        np.testing.assert_array_equal(_drive(seg.lower_bool(q)),
                                      naive_eval(q, lists, n))


def test_compaction_idempotent_on_replay():
    """Compaction is a pure function of the immutable segment contents:
    replaying it on an identical manager converges to the same segment
    layout (bases, sizes, generations) and the same answers."""
    def build():
        docs = _corpus(44)
        seg = _manager(docs, 16, delta_budget=4, compact_fanout=2)
        for d in docs[16:]:
            seg.insert(d)
        return docs, seg
    docs, a = build()
    _, b = build()
    a.compact()                    # run to quiescence
    b.compact_step()               # replay: step-at-a-time to quiescence
    while b.compact_step():
        pass
    layout = lambda s: [(x.base, x.num_docs, x.gen) for x in s.segments]
    assert layout(a) == layout(b)
    assert a.compact_step() is False      # quiescent: replay is a no-op
    rng = np.random.default_rng(SEED + 5)
    lists, n = _invert(docs), len(docs)
    for q in _queries(rng):
        want = naive_eval(q, lists, n)
        np.testing.assert_array_equal(_drive(a.lower_bool(q)), want)
        np.testing.assert_array_equal(_drive(b.lower_bool(q)), want)


# -- knobs + telemetry -------------------------------------------------------

def test_delta_budget_env(monkeypatch):
    docs = _corpus(24)
    monkeypatch.setenv(DELTA_BUDGET_ENV, "3")
    seg = _manager(docs, 16)
    assert seg.delta_budget == 3
    for d in docs[16:24]:
        seg.insert(d)
    assert seg.flushes >= 1            # env budget actually triggered
    assert seg.delta_docs <= 3


def test_telemetry_counts():
    docs = _corpus(40)
    seg = _manager(docs, 16, delta_budget=4, compact_fanout=2)
    for d in docs[16:]:
        seg.insert(d)
    seg.compact()
    t = seg.telemetry()
    assert t["ingested_docs"] == 24
    assert t["flushes"] >= 2 and t["flush_ms"] > 0
    assert t["compactions"] >= 1
    assert t["segments"] == len(seg.segments)
    assert t["delta_docs"] == seg.delta_docs


def test_swap_index_detaches_segmented():
    """A full-index hot swap supersedes the segment manager (it wrapped
    the old engine); serving continues on the new index."""
    docs = _corpus(30)
    bld = make_builder("host")
    srv = _server(res=bld.build_grammar(_invert(docs[:24])), name="host")
    srv.insert(docs[24])
    assert srv.segmented is not None
    lists, n = _invert(docs[:26]), 26
    srv.swap_index(bld.build_grammar(lists))
    assert srv.segmented is None and srv.scheduler.segmented is None
    q = And((Term(0), Term(1)))
    np.testing.assert_array_equal(srv.search(q), naive_eval(q, lists, n))
