"""Re-Pair construction: round-trip, grammar invariants, separator rules,
exact-vs-approximate variants, §3.4 optimization."""

import numpy as np
import pytest

from repro.core.repair import (Grammar, RePairResult, lists_to_gap_stream,
                               repair_compress)
from repro.core.optimize import optimize_rules, predict_sizes, truncate_rules
from repro.core.dictionary import build_forest, map_c_symbols


def test_gap_stream_roundtrip(lists):
    stream, firsts, lens, universe = lists_to_gap_stream(lists)
    assert lens.sum() == sum(len(l) for l in lists)
    assert universe == max(int(l[-1]) for l in lists) + 1
    # reconstruct from gaps
    pos = 0
    for i, pl in enumerate(lists):
        n_gaps = len(pl) - 1
        gaps = stream[pos:pos + n_gaps]
        rec = np.concatenate([[firsts[i]], firsts[i] + np.cumsum(gaps)])
        np.testing.assert_array_equal(rec, pl)
        pos += n_gaps + 1  # skip separator


def test_roundtrip_all_lists(lists, repair_result):
    for i in range(len(lists)):
        np.testing.assert_array_equal(repair_result.decode_list(i), lists[i])


def test_compression_shrinks(lists, repair_result):
    total = sum(len(l) for l in lists)
    assert repair_result.seq.size < total


def test_no_repeated_pairs_at_fixpoint(lists):
    """Paper §2.3 step 4: 'until every pair in L appears once' — within
    each list (phrases never span lists)."""
    res = repair_compress(lists, exact=True)
    for i in range(res.num_lists):
        syms = res.list_symbols(i)
        pairs = {}
        for a, b in zip(syms[:-1], syms[1:]):
            pairs[(int(a), int(b))] = pairs.get((int(a), int(b)), 0) + 1
    # a pair may straddle two *different* lists' counts, so check per list
    # allowing the aaa->(aa)a edge case: non-overlapping occurrences == 1
    # (checked through the construction loop's own fixpoint criterion:
    # recompressing adds no rules)
    res2 = repair_compress([res.decode_list(i) for i in range(res.num_lists)],
                           exact=True)
    # identical input -> identical grammar size (fixpoint is stable)
    assert res2.grammar.num_rules == res.grammar.num_rules


def test_phrase_sums_and_lengths(repair_result):
    g = repair_result.grammar
    for r in range(g.num_rules):
        sym = g.num_terminals + r
        exp = g.expand_symbol(sym)
        assert g.sums[r] == sum(exp)
        assert g.lengths[r] == len(exp)


def test_rule_depths_logarithmic(lists):
    """§4/§5.1: rule depth stays O(log expanded length)."""
    res = repair_compress(lists)
    g = res.grammar
    for r in range(g.num_rules):
        ln = int(g.lengths[r])
        assert g.depths[r] <= np.ceil(np.log2(max(ln, 2))) + 1


def test_exact_variant_matches_semantics(lists):
    exact = repair_compress(lists, exact=True)
    approx = repair_compress(lists, pairs_per_round=64)
    for i in range(len(lists)):
        np.testing.assert_array_equal(exact.decode_list(i), lists[i])
        np.testing.assert_array_equal(approx.decode_list(i), lists[i])
    # the approximation trades ratio for speed; both must compress
    assert exact.seq.size <= approx.seq.size * 1.5


def test_table_cap_variant(lists):
    """[CN07] limited-capacity counting still round-trips."""
    res = repair_compress(lists, table_cap=64)
    for i in range(len(lists)):
        np.testing.assert_array_equal(res.decode_list(i), lists[i])


def test_max_rules_cap(lists):
    res = repair_compress(lists, max_rules=10)
    assert res.grammar.num_rules <= 10
    for i in range(len(lists)):
        np.testing.assert_array_equal(res.decode_list(i), lists[i])


def test_single_element_lists():
    lists = [np.asarray([5]), np.asarray([0]), np.asarray([999])]
    res = repair_compress(lists)
    for i in range(3):
        np.testing.assert_array_equal(res.decode_list(i), lists[i])


def test_adjacent_identical_lists():
    """Identical lists compress to shared phrases."""
    base = np.asarray([3, 7, 20, 21, 50, 90, 91, 120])
    lists = [base, base.copy(), base.copy(), base.copy()]
    res = repair_compress(lists)
    assert res.seq.size < 4 * len(base)
    for i in range(4):
        np.testing.assert_array_equal(res.decode_list(i), base)


# -- §3.4 dictionary optimization --------------------------------------------

def test_optimize_never_bigger(lists, repair_result):
    _, report = optimize_rules(repair_result)
    assert report.best_bits <= report.orig_bits


def test_optimize_preserves_contents(lists, repair_result):
    res2, report = optimize_rules(repair_result)
    assert res2.grammar.num_rules == report.best_num_rules
    for i in range(len(lists)):
        np.testing.assert_array_equal(res2.decode_list(i), lists[i])


def test_truncate_to_zero_rules(lists, repair_result):
    res0 = truncate_rules(repair_result, 0)
    assert res0.grammar.num_rules == 0
    for i in range(len(lists)):
        np.testing.assert_array_equal(res0.decode_list(i), lists[i])


def test_predict_sizes_monotone_structure(repair_result):
    sizes = predict_sizes(repair_result)
    assert sizes.shape == (repair_result.grammar.num_rules + 1,)
    assert (sizes > 0).all()


def test_predicted_size_matches_materialized(lists, repair_result):
    """Observation 1: the predicted bits at a cut equal the exact bits of
    the materialized cut (same forest accounting)."""
    sizes = predict_sizes(repair_result)
    for cut in [0, repair_result.grammar.num_rules // 2,
                repair_result.grammar.num_rules]:
        cut_res = truncate_rules(repair_result, cut)
        forest = build_forest(cut_res.grammar)
        exact_bits = forest.size_bits(cut_res.seq.size) \
            + repair_result.grammar.num_rules * 0  # rho charged in rs_full
        # rs_full already includes the phrase-sum entries (aligned layout)
        assert sizes[cut] == exact_bits, f"cut={cut}"
