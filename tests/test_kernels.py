"""Pallas kernels: shape/dtype sweeps against the ref.py pure-jnp oracles,
interpret=True on CPU (the kernel bodies execute in Python)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.gap_decode.ops import gap_decode
from repro.kernels.gap_decode.ref import gap_decode_ref
from repro.kernels.bitmap_and.ops import bitmap_and
from repro.kernels.bitmap_and.ref import bitmap_and_ref
from repro.kernels.bucket_intersect.ops import bucket_intersect
from repro.kernels.bucket_intersect.ref import bucket_intersect_ref
from repro.kernels.grammar_expand.ops import grammar_expand
from repro.kernels.grammar_expand.ref import grammar_expand_ref
from repro.kernels.grammar_expand.grammar_expand import PHRASE_CAP
from repro.kernels.list_intersect.ops import list_intersect, next_geq
from repro.kernels.list_intersect.ref import (list_intersect_ref,
                                              next_geq_ref)
from repro.core.repair import repair_compress
from repro.core.jax_index import build_flat_index

INT_INF = 2**31 - 1


# -- gap_decode ----------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 7), (3, 130), (8, 512), (5, 700),
                                   (16, 1024), (2, 2000)])
def test_gap_decode_shapes(shape, rng):
    R, C = shape
    gaps = rng.integers(0, 1000, size=(R, C)).astype(np.int32)
    firsts = rng.integers(0, 100, size=(R,)).astype(np.int32)
    got = np.asarray(gap_decode(jnp.asarray(gaps), jnp.asarray(firsts)))
    ref = np.asarray(gap_decode_ref(jnp.asarray(gaps),
                                    jnp.asarray(firsts)[:, None]))
    np.testing.assert_array_equal(got, ref)


def test_gap_decode_cross_tile_carry(rng):
    """Columns > TILE_C exercise the carry scratch."""
    gaps = np.ones((8, 1537), dtype=np.int32)
    firsts = np.zeros(8, dtype=np.int32)
    got = np.asarray(gap_decode(jnp.asarray(gaps), jnp.asarray(firsts)))
    np.testing.assert_array_equal(got[0], np.arange(1, 1538))


# -- bitmap_and ------------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 1024, 4096, 5000])
def test_bitmap_and_sizes(n, rng):
    a = rng.integers(0, 2**32, size=(n,), dtype=np.uint32)
    b = rng.integers(0, 2**32, size=(n,), dtype=np.uint32)
    got = np.asarray(bitmap_and(jnp.asarray(a), jnp.asarray(b)))
    ref = np.asarray(bitmap_and_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_array_equal(got, a & b)


def test_bitmap_and_popcount_semantics(rng):
    """The AND of two bitmaps intersects the encoded sets."""
    from repro.core.bitmaps import build_bitmap
    u = 4096
    s1 = np.sort(rng.choice(u, size=700, replace=False))
    s2 = np.sort(rng.choice(u, size=900, replace=False))
    b1 = build_bitmap(s1, u).words.view(np.uint32)
    b2 = build_bitmap(s2, u).words.view(np.uint32)
    anded = np.asarray(bitmap_and(jnp.asarray(b1), jnp.asarray(b2)))
    bits = np.unpackbits(anded.view(np.uint8), bitorder="little")
    np.testing.assert_array_equal(np.nonzero(bits[:u])[0],
                                  np.intersect1d(s1, s2))


# -- bucket_intersect -------------------------------------------------------------

@pytest.mark.parametrize("nb,cap", [(8, 128), (16, 128), (8, 256), (32, 128)])
def test_bucket_intersect_shapes(nb, cap, rng):
    def mk():
        m = np.full((nb, cap), INT_INF, dtype=np.int32)
        for r in range(nb):
            n = int(rng.integers(0, cap))
            vals = np.sort(rng.choice(10000, size=n, replace=False))
            m[r, :n] = vals + r * 10000
        return m
    a, b = mk(), mk()
    got = np.asarray(bucket_intersect(jnp.asarray(a), jnp.asarray(b)))
    ref = np.asarray(bucket_intersect_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, ref)
    # semantic: per bucket, the surviving values are the set intersection
    for r in range(nb):
        av = a[r][a[r] != INT_INF]
        bv = b[r][b[r] != INT_INF]
        sv = got[r][got[r] != INT_INF]
        np.testing.assert_array_equal(np.sort(sv),
                                      np.intersect1d(av, bv))


# -- list_intersect (fused next_geq) ----------------------------------------------

@pytest.fixture(scope="module")
def li_flat(repair_result):
    return build_flat_index(repair_result)


@pytest.mark.parametrize("nq", [1, 100, 128, 300])
def test_list_intersect_next_geq_bitexact(lists, li_flat, rng, nq):
    """The fused kernel (bucket lookup + phrase-sum skip + descent in one
    pallas_call) must match the jnp engine bit-exactly, across Q paddings."""
    L = len(lists)
    lids = rng.integers(0, L, nq).astype(np.int32)
    xs = rng.integers(0, li_flat.universe + 100, nq).astype(np.int32)
    got = np.asarray(next_geq(li_flat, jnp.asarray(lids), jnp.asarray(xs),
                              interpret=True))
    ref = np.asarray(next_geq_ref(li_flat, jnp.asarray(lids),
                                  jnp.asarray(xs)))
    np.testing.assert_array_equal(got, ref)
    # and vs ground truth
    for q, (li, x) in enumerate(zip(lids, xs)):
        arr = lists[li]
        pos = np.searchsorted(arr, x)
        want = arr[pos] if pos < len(arr) else INT_INF
        assert got[q] == want


def test_list_intersect_probe_matrix(lists, li_flat, rng):
    """2-D membership filtering: INT_INF-padded probe rows against long
    lists, kernel vs jnp reference bit-exact."""
    L = len(lists)
    B, M = 6, 64
    long_ids = rng.integers(0, L, B).astype(np.int32)
    xs = np.full((B, M), INT_INF, dtype=np.int32)
    for r in range(B):
        n = int(rng.integers(1, M))
        xs[r, :n] = np.sort(rng.integers(0, li_flat.universe, n))
    got = np.asarray(list_intersect(li_flat, jnp.asarray(long_ids),
                                    jnp.asarray(xs), interpret=True))
    ref = np.asarray(list_intersect_ref(li_flat, jnp.asarray(long_ids),
                                        jnp.asarray(xs)))
    np.testing.assert_array_equal(got, ref)
    for r in range(B):
        probes = xs[r][xs[r] != INT_INF]
        kept = got[r][got[r] != INT_INF]
        np.testing.assert_array_equal(
            np.unique(kept), np.intersect1d(probes, lists[long_ids[r]]))


# -- grammar_expand ---------------------------------------------------------------

def test_grammar_expand_vs_ref_and_truth(lists):
    res = repair_compress(lists, max_rules=400)
    fi = build_flat_index(res)
    left = np.asarray(fi.sym_left)
    right = np.asarray(fi.sym_right)
    sums = np.asarray(fi.sym_sum)
    lens = np.asarray(fi.sym_len)
    # pick symbols whose expansion fits PHRASE_CAP
    cand = np.nonzero(lens <= PHRASE_CAP)[0]
    syms = cand[: (cand.size // 16) * 16][:64].astype(np.int32)
    if syms.size == 0:
        pytest.skip("no symbols small enough")
    got = np.asarray(grammar_expand(
        jnp.asarray(syms), jnp.asarray(left), jnp.asarray(right),
        jnp.asarray(sums), jnp.asarray(lens), max_depth=fi.max_depth))
    ref = np.asarray(grammar_expand_ref(
        jnp.asarray(syms), jnp.asarray(left), jnp.asarray(right),
        jnp.asarray(sums), jnp.asarray(lens), max_depth=fi.max_depth,
        phrase_cap=PHRASE_CAP))
    np.testing.assert_array_equal(got, ref)
    # ground truth from the host grammar
    T = fi.num_terminals
    for w, s in enumerate(syms):
        if s < T:
            want = [int(sums[s])]
        else:
            want = [int(sums[t]) if t < T else None
                    for t in []]  # placeholder
            # expand via flat tables on host
            stack = [int(s)]
            want = []
            while stack:
                t = stack.pop()
                if left[t] < 0:
                    want.append(int(sums[t]))
                else:
                    stack.append(int(right[t]))
                    stack.append(int(left[t]))
        row = got[w][: len(want)]
        np.testing.assert_array_equal(row, want)
        assert (got[w][len(want):] == 0).all()


@pytest.mark.parametrize("dtype", [np.int32])
def test_grammar_expand_terminals_only(dtype, rng):
    """Terminals expand to themselves."""
    S = 64
    left = np.full(S, -1, dtype=np.int32)
    right = np.full(S, -1, dtype=np.int32)
    sums = np.arange(S, dtype=np.int32)
    lens = np.ones(S, dtype=np.int32)
    syms = rng.integers(0, S, size=16).astype(dtype)
    got = np.asarray(grammar_expand(
        jnp.asarray(syms), jnp.asarray(left), jnp.asarray(right),
        jnp.asarray(sums), jnp.asarray(lens), max_depth=4))
    for w, s in enumerate(syms):
        assert got[w, 0] == s
        assert (got[w, 1:] == 0).all()
