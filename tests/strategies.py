"""Shared test-data generators.

One home for the corpus/postings/AST generators that used to be
copy-pasted across test modules:

* plain-numpy generators (always available): ``make_lists`` (the conftest
  corpus), ``small_lists`` (the build-parity corpus), ``adversarial_lists``
  (randomized lists + the engine edge-case shapes), ``random_ast`` (seeded
  boolean query trees for the differential gate's no-hypothesis fallback);
* hypothesis strategies (guarded — ``hypothesis`` is an optional dev
  dependency): ``posting_lists`` and the recursive ``query_asts``.

Import the numpy generators directly; check ``HAVE_HYPOTHESIS`` (or let
``pytest.importorskip("hypothesis")`` run first) before touching the
strategies.
"""

from __future__ import annotations

import numpy as np

try:
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # tier-1 must stay green on a bare interpreter
    st = None
    HAVE_HYPOTHESIS = False


# -- plain numpy generators ---------------------------------------------------

def make_lists(rng, n_lists=30, universe=4000, min_len=5, max_len=600):
    """Synthetic posting lists with correlated structure (some lists share
    documents, mimicking topical co-occurrence)."""
    lists = []
    hot = np.sort(rng.choice(universe, size=universe // 4, replace=False))
    for i in range(n_lists):
        ln = int(rng.integers(min_len, max_len))
        if i % 3 == 0:  # correlated list: drawn mostly from the hot set
            k = min(ln, hot.size)
            base = rng.choice(hot, size=k, replace=False)
        else:
            base = rng.choice(universe, size=ln, replace=False)
        lists.append(np.unique(base.astype(np.int64)))
    return lists


def small_lists(seed=0, n_lists=10, universe=500, max_len=90):
    """The build-parity corpus: small enough for the device builders'
    fixed-shape rounds, correlated enough to produce real rules."""
    rng = np.random.default_rng(seed)
    out = []
    hot = np.sort(rng.choice(universe, size=universe // 4, replace=False))
    for i in range(n_lists):
        ln = int(rng.integers(2, max_len))
        pool = hot if i % 3 == 0 else np.arange(universe)
        out.append(np.unique(rng.choice(pool, size=min(ln, pool.size),
                                        replace=False).astype(np.int64)))
    return out


def adversarial_lists(rng, universe=1200, n_random=10, max_len=60):
    """Randomized lists plus the engine edge-case shapes: a singleton, a
    2-element list at the universe edges, and a provably disjoint pair
    (indices ``n_random`` .. ``n_random+3``)."""
    lists = []
    for _ in range(n_random):
        ln = int(rng.integers(2, max_len))
        lists.append(np.unique(rng.choice(universe, size=ln, replace=False)))
    lists.append(np.asarray([universe // 3]))                    # singleton
    lists.append(np.asarray([0, universe - 1]))                  # edges
    lists.append(np.arange(0, universe, 7, dtype=np.int64)[:50])
    lists.append(np.arange(3, universe, 7, dtype=np.int64)[:50])  # disjoint ^
    return lists


def random_ast(rng, num_lists, max_depth=3):
    """Seeded random boolean AST over ``num_lists`` term ids (including a
    slice of out-of-vocabulary ids, which must evaluate to the empty set).
    The numpy fallback generator for the differential gate when hypothesis
    is not installed."""
    from repro.query.ast import And, Not, Or, Phrase, Term

    def term_id():
        # ~1 in 8 draws is out of vocabulary (-1 or past the last list)
        if rng.random() < 0.125:
            return int(rng.choice([-1, num_lists, num_lists + 3]))
        return int(rng.integers(0, num_lists))

    def node(depth):
        ops = ["term", "phrase"] if depth >= max_depth else \
            ["term", "term", "phrase", "and", "and", "or", "not"]
        op = ops[int(rng.integers(len(ops)))]
        if op == "term":
            return Term(term_id())
        if op == "phrase":
            k = int(rng.integers(2, 4))
            return Phrase(tuple(term_id() for _ in range(k)))
        if op == "not":
            return Not(node(depth + 1))
        k = int(rng.integers(2, 4))
        kids = tuple(node(depth + 1) for _ in range(k))
        return And(kids) if op == "and" else Or(kids)

    return node(0)


# -- hypothesis strategies ----------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def posting_lists(draw, max_lists=8, max_universe=600, max_len=120):
        """2..max_lists sorted unique int64 arrays over one universe."""
        n = draw(st.integers(2, max_lists))
        u = draw(st.integers(16, max_universe))
        out = []
        for _ in range(n):
            ln = draw(st.integers(1, min(max_len, u)))
            ids = draw(st.sets(st.integers(0, u - 1),
                               min_size=ln, max_size=ln))
            out.append(np.asarray(sorted(ids), dtype=np.int64))
        return out

    def query_asts(num_lists, max_leaves=6):
        """Recursive boolean/phrase AST strategy over ``num_lists`` term
        ids, including out-of-vocabulary ids (shrinks toward bare terms)."""
        from repro.query.ast import And, Not, Or, Phrase, Term

        terms = st.integers(-1, num_lists + 1)
        leaves = st.one_of(
            st.builds(Term, terms),
            st.builds(lambda ts: Phrase(tuple(ts)),
                      st.lists(terms, min_size=2, max_size=3)),
        )
        return st.recursive(
            leaves,
            lambda inner: st.one_of(
                st.builds(lambda cs: And(tuple(cs)),
                          st.lists(inner, min_size=2, max_size=3)),
                st.builds(lambda cs: Or(tuple(cs)),
                          st.lists(inner, min_size=2, max_size=3)),
                st.builds(Not, inner),
            ),
            max_leaves=max_leaves,
        )
