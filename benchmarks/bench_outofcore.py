"""Out-of-core serving benchmark: the page-level admission cache
(DESIGN.md §11).

A corpus-size sweep holds the resident budget at ~10% of the compressed
stream's pages (the index is >=10x over budget at every point) with the
stream behind an **mmap page store** — the configuration a
larger-than-memory corpus would run.  A Zipf boolean workload
(``common.boolean_workload``) drives the coalescing scheduler per
engine; reported per cell: qps, p50/p95 latency, and the cache
telemetry that makes the number interpretable — page faults, evictions,
bytes faulted, pool grows, and the sliding-window hit rate (the Zipf
head of the page working set should turn into hits, so a measured
hit rate of 0 would mean the cache is not doing its job).

Every result is oracle-checked on a warmup pass before timing, so a qps
number can never come from a wrong answer; the warmup runs on the SAME
engine (hence the same pool), so the timed pass measures the
steady-state cache, not a cold one.  Honest-numbers note (DESIGN.md
§11.5): on this box the mmap "disk" is the OS page cache, so fault
costs are memcpy-bound lower bounds — the portable signal is the
mechanism (bounded resident set, batched faulting, non-zero hit rate at
10x over-budget), not the absolute fault latency.

  PYTHONPATH=src python -m benchmarks.run --only outofcore
  PYTHONPATH=src python -m benchmarks.bench_outofcore --engine host,jnp
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.repair import repair_compress
from repro.engine import make_engine, validate_engines
from repro.query import naive_eval
from repro.serve.scheduler import QueryScheduler
from repro.store import normalize_page_size

from .common import BENCH_SEED, boolean_workload, corpus_lists, emit

DEFAULT_ENGINES = ("host", "jnp", "pallas")
PAGE = 128
CONCURRENCY = 8

#: corpus-size sweep; the pallas engine (interpret mode on CPU) only
#: runs the smallest point — same policy as the other device benches
CORPORA = (
    dict(num_docs=300, vocab_size=900, mean_doc_len=50),
    dict(num_docs=700, vocab_size=1400, mean_doc_len=60),
    dict(num_docs=1500, vocab_size=2200, mean_doc_len=70),
)


def _budget(res) -> tuple[int, int]:
    """(~10% resident budget, total pages) of ``res``'s stream at PAGE."""
    page = normalize_page_size(PAGE)
    num_pages = max(1, -(-int(res.seq.size) // page))
    return max(1, num_pages // 10), num_pages


def run(engines=DEFAULT_ENGINES, n_queries=48) -> list[dict]:
    rows = []
    for ci, corpus in enumerate(CORPORA):
        lists, _ = corpus_lists(**corpus)
        res = repair_compress(lists)
        budget, num_pages = _budget(res)
        queries = boolean_workload(len(lists), [len(l) for l in lists],
                                   n_queries=n_queries)
        oracle = [naive_eval(q, lists, res.universe) for q in queries]
        for name in engines:
            if name == "pallas" and ci > 0:
                continue
            # prefetch axis (DESIGN.md §13.3): fresh engine per mode so
            # the two cells start from identical (cold) pools
            for prefetch in (True, False):
                eng = make_engine(name, res, store="mmap",
                                  resident_pages=budget, page_size=PAGE)
                # warmup: jit compilation + the correctness gate, and it
                # brings the pool to steady state for the timed pass
                warm = QueryScheduler(eng, batch_window=CONCURRENCY,
                                      result_cache_size=0,
                                      prefetch=prefetch)
                for got, want in zip(warm.search_many(queries), oracle):
                    np.testing.assert_array_equal(got, want)
                sch = QueryScheduler(eng, batch_window=CONCURRENCY,
                                     result_cache_size=0,
                                     prefetch=prefetch)
                t0 = time.perf_counter()
                sch.search_many(queries)
                dt = time.perf_counter() - t0
                st = sch.stats()
                cache = eng.resident.stats()
                rows.append({
                    "engine": name,
                    "num_docs": corpus["num_docs"],
                    "prefetch": prefetch,
                    "n_queries": len(queries),
                    "qps": len(queries) / dt,
                    "p50_ms": st["p50_ms"],
                    "p95_ms": st["p95_ms"],
                    "num_pages": num_pages,
                    "budget_requested": budget,
                    "budget": cache["budget"],
                    "over_budget_ratio": num_pages / cache["budget"],
                    "resident_pages": cache["resident_pages"],
                    "page_faults": cache["page_faults"],
                    "page_evictions": cache["page_evictions"],
                    "fault_bytes": cache["fault_bytes"],
                    "pool_grows": cache["pool_grows"],
                    "fault_rate": cache["page_faults"]
                    / max(1, cache["lookups"]),
                    "hit_rate_window": cache["hit_rate_window"],
                    # overlapped-prefetch telemetry (timed pass only):
                    # overlap_ms is gather time hidden behind dispatch —
                    # the fault stall the background thread removed
                    "prefetched_pages": st["prefetched_pages"],
                    "prefetch_accuracy": st["prefetch_accuracy"],
                    "prefetch_gather_ms": st["prefetch_gather_ms"],
                    "overlap_ms": st["overlap_ms"],
                })
                emit(rows[-1:],
                     f"{name} × {corpus['num_docs']} docs "
                     f"({num_pages} pages @ budget {cache['budget']}, "
                     f"prefetch={'on' if prefetch else 'off'})")
    return rows


def main(engines=DEFAULT_ENGINES, n_queries=48) -> dict:
    validate_engines(engines)
    rows = run(engines, n_queries)
    assert all(r["over_budget_ratio"] >= 10 or r["pool_grows"] > 0
               for r in rows), "sweep must stay >=10x over budget"
    assert all(r["hit_rate_window"] > 0 for r in rows), \
        "admission cache measured no hits"
    # overlapped prefetch removed real fault stall at the 10x point, and
    # speculative admission never grew a pool its OFF twin didn't grow
    on_rows = [r for r in rows if r["prefetch"]]
    assert any(r["overlap_ms"] > 0 for r in on_rows), \
        "prefetch overlapped no gather time"
    for on in on_rows:
        off = next(r for r in rows if not r["prefetch"]
                   and r["engine"] == on["engine"]
                   and r["num_docs"] == on["num_docs"])
        assert on["pool_grows"] <= off["pool_grows"], (on, off)
    return {
        "seed": BENCH_SEED,
        "page_size": PAGE,
        "concurrency": CONCURRENCY,
        "corpora": list(CORPORA),
        "rows": rows,
        "qps": {f"{r['engine']}/{r['num_docs']}d"
                f"/{'on' if r['prefetch'] else 'off'}": r["qps"]
                for r in rows},
        "hit_rate": {f"{r['engine']}/{r['num_docs']}d"
                     f"/{'on' if r['prefetch'] else 'off'}":
                     r["hit_rate_window"] for r in rows},
        "overlap_ms": {f"{r['engine']}/{r['num_docs']}d": r["overlap_ms"]
                       for r in on_rows},
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", type=str, default=",".join(DEFAULT_ENGINES))
    ap.add_argument("--n", type=int, default=48)
    args = ap.parse_args()
    main(engines=tuple(args.engine.split(",")), n_queries=args.n)
