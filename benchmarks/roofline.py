"""Beyond-paper: batched TPU query-engine micro-roofline on the REAL
device (CPU here; v5e numbers reported by the dry-run analysis).

Measures throughput of the device query engine (batched next_geq /
membership / pair-intersect) and the Pallas kernels in interpret mode,
with arithmetic-intensity estimates — the measured complement of
EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.jax_index import build_flat_index
from repro.core.repair import repair_compress
from repro.engine import jnp_backend as J

from .common import corpus_lists, emit


def run() -> list[dict]:
    lists, u = corpus_lists(num_docs=1000, vocab_size=2000)
    res = repair_compress(lists)
    fi = build_flat_index(res)
    rng = np.random.default_rng(0)

    rows = []
    B = 4096
    lids = jnp.asarray(rng.integers(0, len(lists), B), jnp.int32)
    xs = jnp.asarray(rng.integers(0, u, B), jnp.int32)

    J.next_geq_batch(fi, lids, xs).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(20):
        J.next_geq_batch(fi, lids, xs).block_until_ready()
    dt = (time.perf_counter() - t0) / 20
    rows.append({"op": "next_geq", "batch": B,
                 "qps": B / dt, "us_per_query": dt / B * 1e6})

    J.member_batch(fi, lids, xs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        J.member_batch(fi, lids, xs).block_until_ready()
    dt = (time.perf_counter() - t0) / 20
    rows.append({"op": "member", "batch": B,
                 "qps": B / dt, "us_per_query": dt / B * 1e6})

    # pairwise intersect
    BP = 256
    short_cap = 128
    cand = [i for i in range(len(lists)) if len(lists[i]) <= short_cap]
    si = jnp.asarray(rng.choice(cand, BP), jnp.int32)
    li = jnp.asarray(rng.integers(0, len(lists), BP), jnp.int32)
    J.pair_intersect(fi, si, li, short_cap).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        J.pair_intersect(fi, si, li, short_cap).block_until_ready()
    dt = (time.perf_counter() - t0) / 5
    rows.append({"op": "pair_intersect", "batch": BP,
                 "qps": BP / dt, "us_per_query": dt / BP * 1e6})

    emit(rows, "device query engine throughput (CPU backend)")
    return rows


def main() -> None:
    rows = run()
    assert all(r["qps"] > 0 for r in rows)


if __name__ == "__main__":
    main()
