"""Paper §5.1 rule-height experiment: pack 1..128 documents into one and
verify the maximum rule height grows logarithmically (paper: 15 at pack=128
-> ~25 at pack=8; optimized 9 -> 19)."""

from __future__ import annotations

import numpy as np

from repro.core.optimize import optimize_rules
from repro.core.repair import repair_compress

from .common import corpus_lists, emit


def run() -> list[dict]:
    rows = []
    for pack in (1, 4, 16, 64):
        lists, u = corpus_lists(num_docs=2048, vocab_size=3000, pack=pack)
        res = repair_compress(lists)
        opt, _ = optimize_rules(res)
        rows.append({
            "pack": pack,
            "num_docs": u,
            "max_height": int(res.grammar.depths.max(initial=0)),
            "max_height_optimized": int(opt.grammar.depths.max(initial=0)),
            "log2_postings": float(np.log2(sum(len(l) for l in lists))),
        })
    emit(rows, "sec5.1: rule height vs doc packing")
    return rows


def main() -> None:
    rows = run()
    # logarithmic growth: height under c*log2(n) for a small constant, and
    # fewer (larger) documents -> no taller grammars than the many-doc case
    for r in rows:
        assert r["max_height"] <= 3 * r["log2_postings"], r
        assert r["max_height_optimized"] <= r["max_height"]


if __name__ == "__main__":
    main()
