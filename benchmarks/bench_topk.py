"""Ranked-retrieval benchmark: BM25 top-k with block-max page pruning
(DESIGN.md §9.4).

A Zipf ranked workload (``common.ranked_workload`` — bags of 2..4 terms,
popularity-weighted so the stream hits the multi-page head lists) runs
through the coalescing scheduler per engine backend at k in {10, 100}.
Reported per cell: qps, p50/p95 latency, coalescing factor, and the
pruning headline — pages scored vs pages skipped.  ``pages_skipped_frac``
is exactly the fraction of page decodes an exhaustive (prune=False) run
would have paid that the admission bound refused: the driver's invariant
``scored_pruned + skipped == scored_exhaustive`` is asserted here on the
host engine and pinned for every backend in tests/test_topk.py.

Every ranked answer is checked against the brute-force ``rank_oracle``
(exact float32 scores AND tie-broken order) on a warmup pass before
timing, so a qps number can never come from a wrong ranking.

Honest-numbers notes (2-core CPU box, same spirit as BENCH_serve):

* the host engine wins raw qps — the device engines pay interpreter/XLA
  dispatch costs per ScoreRound that batching amortizes but cannot erase;
* the pallas engine runs the fused page-decode kernel under the Pallas
  INTERPRETER here (no TPU), which is orders of magnitude slower than a
  compiled launch (tens of SECONDS per query: every ScoreRound re-traces
  the kernel in python) — it is timed on a fixed ``N_PALLAS``-query
  prefix of the workload at ``k=PALLAS_K`` only, purely to keep the
  gate + timing affordable; its qps is an interpreter artifact, NOT a
  hardware projection, while its pruning columns remain per-query
  comparable with the other engines (the admission decisions are
  engine-independent).  Use ``--engines host,jnp`` to skip it entirely;
* ``pages_skipped_frac`` is the hardware-portable signal: each skipped
  entry is one stream page that never moves (host: never sliced; device:
  never DMA'd), independent of what a page decode costs.

  PYTHONPATH=src python -m benchmarks.run --only topk
  PYTHONPATH=src python -m benchmarks.bench_topk --engines host,jnp
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.jax_index import build_flat_index, build_score_index
from repro.core.repair import repair_compress
from repro.engine import make_engine, validate_engines
from repro.query import rank_oracle
from repro.serve.scheduler import QueryScheduler

from .common import BENCH_SEED, corpus_lists, emit, ranked_workload

DEFAULT_ENGINES = ("host", "jnp", "pallas")
TOP_K = (10, 100)

#: directory/page geometry: fine-grained pages so head lists span several
#: block-max entries (at the 2048-symbol serving default this corpus is
#: one page per list and there is nothing to prune)
PAGE = 128

#: queries timed on the interpreter-mode pallas engine (prefix of the
#: workload; see the honesty note above) — at tens of seconds per
#: interpreted query, anything more makes the bench unrunnable
N_PALLAS = 2
#: the one k the pallas cell is timed at (k is a post-scoring top-k
#: select; the interpreted kernel cost is k-independent, so one cell
#: carries the same information as two)
PALLAS_K = 10

CORPUS = dict(num_docs=2000, vocab_size=600, mean_doc_len=50)


def _mk_engines(names, res, fi, si):
    out = {}
    for name in names:
        if name == "host":
            eng = make_engine("host", res)
            eng.score_page_size = PAGE
        elif name == "jnp":
            eng = make_engine("jnp", res, fi=fi, paged=True, page_size=PAGE)
        else:
            eng = make_engine(name, res, fi=fi, page_size=PAGE)
        eng.set_score_index(si)   # one shared directory: same admission
        out[name] = eng           # decisions on every backend
    return out


def run(engines=DEFAULT_ENGINES, n_queries=32) -> list[dict]:
    lists, num_docs = corpus_lists(**CORPUS)
    res = repair_compress(lists)
    fi = build_flat_index(res)
    si = build_score_index(res, page_size=PAGE)
    queries = ranked_workload(len(lists), [len(l) for l in lists],
                              n_queries=n_queries)
    engs = _mk_engines(engines, res, fi, si)

    rows = []
    for k in TOP_K:
        oracle = [rank_oracle(lists, num_docs, q, k) for q in queries]
        for name, eng in engs.items():
            if name == "pallas" and k != PALLAS_K:
                continue
            qs = queries[:N_PALLAS] if name == "pallas" else queries
            # warmup pass: jit compilation + the relevance gate
            warm = QueryScheduler(eng, batch_window=8, result_cache_size=0)
            for r, (od, osc) in zip(warm.search_topk_many(qs, k), oracle):
                np.testing.assert_array_equal(r.docs, od)
                np.testing.assert_array_equal(r.scores, osc)
            if name == "host":
                # pruning honesty: pruned + skipped == exhaustive pages
                exh = QueryScheduler(eng, batch_window=8,
                                     result_cache_size=0)
                for r, rx in zip(warm.search_topk_many(qs, k),
                                 exh.search_topk_many(qs, k, prune=False)):
                    assert (r.pages_scored + r.pages_skipped
                            == rx.pages_scored)
            # timed pass on a fresh scheduler (result cache off: we are
            # timing execution + pruning, not memoization)
            sch = QueryScheduler(eng, batch_window=8, result_cache_size=0)
            t0 = time.perf_counter()
            sch.search_topk_many(qs, k)
            dt = time.perf_counter() - t0
            st = sch.stats()
            rows.append({
                "engine": name,
                "k": k,
                "n_queries": len(qs),
                "qps": len(qs) / dt,
                "p50_ms": st["p50_ms"],
                "p95_ms": st["p95_ms"],
                "coalescing_factor": st["coalescing_factor"],
                "pages_scored": st["pages_scored"],
                "pages_skipped": st["pages_skipped"],
                "pages_skipped_frac": st["pages_skipped_frac"],
            })
            emit(rows[-1:], f"{name} × k={k}")
    return rows


def main(engines=DEFAULT_ENGINES, n_queries=32) -> dict:
    validate_engines(engines)
    rows = run(engines, n_queries)
    return {
        "seed": BENCH_SEED,
        "corpus": CORPUS,
        "page_size": PAGE,
        "top_k": list(TOP_K),
        "rows": rows,
        "qps": {f"{r['engine']}/k{r['k']}": r["qps"] for r in rows},
        "pages_skipped_frac": {f"{r['engine']}/k{r['k']}":
                               r["pages_skipped_frac"] for r in rows},
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engines", "--engine", dest="engines", type=str,
                    default=",".join(DEFAULT_ENGINES),
                    help="comma-separated backend filter, e.g. host,jnp")
    ap.add_argument("--n", type=int, default=32)
    args = ap.parse_args()
    main(engines=tuple(args.engines.split(",")), n_queries=args.n)
