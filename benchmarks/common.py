"""Shared benchmark utilities: corpus builder cache, timing, CSV output."""

from __future__ import annotations

import time

import numpy as np

from repro.index.corpus import zipf_corpus, pack_documents, randomize_lists

_CACHE: dict = {}


def corpus_lists(num_docs=2000, vocab_size=5000, mean_doc_len=120, seed=0,
                 pack=1):
    """Postings of the synthetic TREC-like collection (cached)."""
    key = (num_docs, vocab_size, mean_doc_len, seed, pack)
    if key not in _CACHE:
        c = zipf_corpus(num_docs=num_docs, vocab_size=vocab_size,
                        mean_doc_len=mean_doc_len, seed=seed)
        if pack > 1:
            c = pack_documents(c, pack)
        lists = c.postings()
        _CACHE[key] = (lists, c.num_docs)
    return _CACHE[key]


def time_us(fn, *args, repeat=3, number=20) -> float:
    """Median-of-repeat mean μs per call."""
    best = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn(*args)
        best.append((time.perf_counter() - t0) / number * 1e6)
    return float(np.median(best))


def emit(rows: list[dict], header: str) -> None:
    print(f"\n# {header}")
    if not rows:
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))
