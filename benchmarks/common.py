"""Shared benchmark utilities: corpus builder cache, timing, CSV output.

Every corpus a bench generates is keyed by ONE explicit numpy seed,
``BENCH_SEED`` (env ``REPRO_BENCH_SEED``, default 0), threaded through
``corpus_lists`` — so any two machines running the same bench produce
byte-identical BENCH_*.json inputs, and a recorded regression is a code
regression, not a corpus roll.  Benches should record the seed into
their JSON payload (see ``bench_build``).
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.index.corpus import zipf_corpus, pack_documents, randomize_lists

#: The one corpus seed of a benchmark run; BENCH_*.json results are a
#: pure function of (code, BENCH_SEED).
BENCH_SEED = int(os.environ.get("REPRO_BENCH_SEED", "0"))

_CACHE: dict = {}


def corpus_lists(num_docs=2000, vocab_size=5000, mean_doc_len=120,
                 seed=None, pack=1):
    """Postings of the synthetic TREC-like collection (cached).
    ``seed=None`` means the run-wide ``BENCH_SEED``."""
    seed = BENCH_SEED if seed is None else seed
    key = (num_docs, vocab_size, mean_doc_len, seed, pack)
    if key not in _CACHE:
        c = zipf_corpus(num_docs=num_docs, vocab_size=vocab_size,
                        mean_doc_len=mean_doc_len, seed=seed)
        if pack > 1:
            c = pack_documents(c, pack)
        lists = c.postings()
        _CACHE[key] = (lists, c.num_docs)
    return _CACHE[key]


def boolean_workload(num_lists, lengths, n_queries=64, seed=None,
                     max_terms=4, p_or=0.2, p_not=0.12, p_phrase=0.12,
                     zipf_s=1.1):
    """Zipf-distributed boolean/phrase query stream over ``num_lists``
    postings lists (DESIGN.md §7.4).

    Term draws follow a Zipf law over the POPULARITY ranking (longer list =
    more frequent term = more often queried), matching how real query logs
    hit the head of the vocabulary.  Query shapes: k-term AND (k in
    [2, max_terms]), OR of two ANDs, AND with one negated term, and
    adjacent-term phrases.  Returns a list of AST nodes; a pure function of
    the arguments (``seed=None`` means the run-wide ``BENCH_SEED``).
    """
    from repro.query.ast import And, Not, Or, Phrase, Term

    rng = np.random.default_rng(BENCH_SEED if seed is None else seed)
    order = np.argsort(-np.asarray(lengths))         # popularity ranking
    p = np.arange(1, num_lists + 1, dtype=np.float64) ** (-zipf_s)
    p /= p.sum()

    def draw_terms(k):
        ranks = rng.choice(num_lists, size=k, replace=False, p=p)
        return [int(order[r]) for r in ranks]

    out = []
    for _ in range(n_queries):
        u = rng.random()
        k = int(rng.integers(2, max_terms + 1))
        if u < p_phrase:
            t0 = int(order[rng.choice(num_lists, p=p)])
            out.append(Phrase(tuple(min(t0 + j, num_lists - 1)
                                    for j in range(k))))
        elif u < p_phrase + p_not:
            ts = draw_terms(k)
            out.append(And(tuple([Term(t) for t in ts[:-1]]
                                 + [Not(Term(ts[-1]))])))
        elif u < p_phrase + p_not + p_or:
            a, b = draw_terms(2), draw_terms(2)
            out.append(Or((And((Term(a[0]), Term(a[1]))),
                           And((Term(b[0]), Term(b[1]))))))
        else:
            out.append(And(tuple(Term(t) for t in draw_terms(k))))
    return out


def ranked_workload(num_lists, lengths, n_queries=32, seed=None,
                    max_terms=4, zipf_s=1.1):
    """Zipf-distributed ranked (bag-of-words) query stream: each query is
    a bag of 2..max_terms distinct term ids, drawn — like
    ``boolean_workload`` — by a Zipf law over the POPULARITY ranking, so
    the stream hits the multi-page head lists the block-max directory
    actually prunes.  Returns a list of term-id lists; a pure function of
    the arguments (``seed=None`` means the run-wide ``BENCH_SEED``)."""
    rng = np.random.default_rng(BENCH_SEED if seed is None else seed)
    order = np.argsort(-np.asarray(lengths))         # popularity ranking
    p = np.arange(1, num_lists + 1, dtype=np.float64) ** (-zipf_s)
    p /= p.sum()
    out = []
    for _ in range(n_queries):
        k = int(rng.integers(2, max_terms + 1))
        ranks = rng.choice(num_lists, size=k, replace=False, p=p)
        out.append([int(order[r]) for r in ranks])
    return out


def time_us(fn, *args, repeat=3, number=20) -> float:
    """Median-of-repeat mean μs per call."""
    best = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn(*args)
        best.append((time.perf_counter() - t0) / number * 1e6)
    return float(np.median(best))


def emit(rows: list[dict], header: str) -> None:
    print(f"\n# {header}")
    if not rows:
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))
