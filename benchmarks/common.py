"""Shared benchmark utilities: corpus builder cache, timing, CSV output.

Every corpus a bench generates is keyed by ONE explicit numpy seed,
``BENCH_SEED`` (env ``REPRO_BENCH_SEED``, default 0), threaded through
``corpus_lists`` — so any two machines running the same bench produce
byte-identical BENCH_*.json inputs, and a recorded regression is a code
regression, not a corpus roll.  Benches should record the seed into
their JSON payload (see ``bench_build``).
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.index.corpus import zipf_corpus, pack_documents, randomize_lists

#: The one corpus seed of a benchmark run; BENCH_*.json results are a
#: pure function of (code, BENCH_SEED).
BENCH_SEED = int(os.environ.get("REPRO_BENCH_SEED", "0"))

_CACHE: dict = {}


def corpus_lists(num_docs=2000, vocab_size=5000, mean_doc_len=120,
                 seed=None, pack=1):
    """Postings of the synthetic TREC-like collection (cached).
    ``seed=None`` means the run-wide ``BENCH_SEED``."""
    seed = BENCH_SEED if seed is None else seed
    key = (num_docs, vocab_size, mean_doc_len, seed, pack)
    if key not in _CACHE:
        c = zipf_corpus(num_docs=num_docs, vocab_size=vocab_size,
                        mean_doc_len=mean_doc_len, seed=seed)
        if pack > 1:
            c = pack_documents(c, pack)
        lists = c.postings()
        _CACHE[key] = (lists, c.num_docs)
    return _CACHE[key]


def time_us(fn, *args, repeat=3, number=20) -> float:
    """Median-of-repeat mean μs per call."""
    best = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn(*args)
        best.append((time.perf_counter() - t0) / number * 1e6)
    return float(np.median(best))


def emit(rows: list[dict], header: str) -> None:
    print(f"\n# {header}")
    if not rows:
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))
