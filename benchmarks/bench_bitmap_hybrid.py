"""Paper Fig. 3/4 (right) + Fig. 5: the MC07 bitmap hybrid.  Long lists
(> num_docs/8) become bitmaps; the rest stay Re-Pair / byte-coded.

Reproduces the paper's NEGATIVE result for Re-Pair: converting the long
lists to bitmaps helps byte codes more than Re-Pair (Re-Pair loses exactly
the highly repetitive gaps that fed its compression).

``--engine host,jnp,pallas`` additionally times the same query pairs
through the backend-pluggable ``repro.engine`` tier (pure Re-Pair, no
bitmaps) so the hybrid's win is measured against every backend.

  PYTHONPATH=src python -m benchmarks.bench_bitmap_hybrid --engine jnp
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.engine import DeviceEngine, make_engine, validate_engines
from repro.index.builder import build_index
from repro.index.hybrid import HybridQueryEngine as QueryEngine

from .common import corpus_lists, emit, time_us


def run(engines: tuple[str, ...] = ("jnp",)) -> dict:
    lists, u = corpus_lists()
    n_post = sum(len(l) for l in lists)

    pure = build_index(lists, u, hybrid_bitmaps=False,
                       codecs=("vbyte", "rice"))
    hyb = build_index(lists, u, hybrid_bitmaps=True,
                      codecs=("vbyte", "rice"))

    sp_pure = pure.space_report()
    sp_hyb = hyb.space_report()

    rows = []
    for name, bits_pure, bits_hyb in [
        ("repair", sp_pure["repair_bits"],
         sp_hyb["repair_bits"] + sp_hyb["bitmap_bits"]),
        ("vbyte", sp_pure["vbyte_bits"],
         # hybrid: short lists byte-coded + bitmaps for long ones
         sum(hyb.codecs["vbyte"].payloads[i].size * 8
             for i in range(len(lists)) if i not in hyb.bitmaps)
         + sp_hyb["bitmap_bits"]),
    ]:
        rows.append({
            "method": name,
            "pure_bits_per_posting": bits_pure / n_post,
            "hybrid_bits_per_posting": bits_hyb / n_post,
            "hybrid_gain_pct": 100.0 * (1 - bits_hyb / bits_pure),
        })
    emit(rows, "fig4-right: hybrid (bitmaps for long lists) space effect")

    # timing: hybrid vs pure on mixed query pairs
    rng = np.random.default_rng(2)
    pairs = [tuple(map(int, rng.choice(len(lists), 2, replace=False)))
             for _ in range(40)]
    qp = QueryEngine(pure, method="lookup")
    qh = QueryEngine(hyb, method="lookup")
    t_pure = float(np.mean([time_us(qp.conjunctive, list(p), repeat=1,
                                    number=3) for p in pairs]))
    t_hyb = float(np.mean([time_us(qh.conjunctive, list(p), repeat=1,
                                   number=3) for p in pairs]))
    timing = {"pure_us": t_pure, "hybrid_us": t_hyb}

    # engine axis: the same pairs, batched through each repro.engine backend
    # over the PURE index (hyb.repair holds 2-element stubs for the lists
    # that were routed to bitmaps — timing those would be meaningless)
    for name in engines:
        eng = make_engine(name, pure.repair)
        if isinstance(eng, DeviceEngine):   # warmup: jit compile at the
            eng.intersect_pairs(pairs)      # timed batch shape

        t0 = time.perf_counter()
        eng.intersect_pairs(pairs)
        timing[f"engine_{name}_us"] = (
            1e6 * (time.perf_counter() - t0) / len(pairs))
    emit([timing], "fig3-right: hybrid query time (us/query) + engine axis")

    gains = {r["method"]: r["hybrid_gain_pct"] for r in rows}
    return gains


def main(engines: tuple[str, ...] = ("jnp",)) -> None:
    validate_engines(engines)  # before the (slow) index builds run
    gains = run(engines=engines)
    # the paper's negative result: byte codes gain more from bitmaps than
    # Re-Pair does (when the split triggers at this scale)
    if gains and "repair" in gains and "vbyte" in gains:
        print(f"\nhybrid gains: repair {gains['repair']:.1f}% "
              f"vs vbyte {gains['vbyte']:.1f}% "
              f"(paper predicts vbyte >= repair)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", type=str, default="jnp",
                    help="comma-separated backends: host,jnp,pallas")
    main(engines=tuple(ap.parse_args().engine.split(",")))
