"""Paper Fig. 3 (left): intersection time as a function of the length
ratio n/m, for every method: merge / skip / svs(exp) / lookup over
Re-Pair, vs byte-code exp and merge baselines — plus the backend-pluggable
engine axis (``--engine host,jnp,pallas``): the same query stream timed
through each ``repro.engine`` backend, batched, so the host cursor tier,
the jnp device tier, and the fused Pallas kernel are directly comparable.

Plus the paged-kernel **N-scaling sweep** (``--scaling``): corpora of
growing compressed-stream length timed through each device engine at a
fixed small page size, so the grid-blocked kernel's scaling curve (pages
grow, per-instance VMEM does not) is tracked across PRs in
``BENCH_intersection.json``.

  PYTHONPATH=src python -m benchmarks.run --only fig3
  PYTHONPATH=src python -m benchmarks.bench_intersection --engine host,jnp
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import codecs as CD
from repro.core import intersect as I
from repro.core.jax_index import build_flat_index, build_paged_index
from repro.core.repair import repair_compress
from repro.core.sampling import build_a_sampling, build_b_sampling
from repro.engine import DeviceEngine, make_engine, validate_engines

from .common import corpus_lists, emit, time_us

DEFAULT_ENGINES = ("host", "jnp")

#: corpus-size axis for the N-scaling sweep (num_docs of the synthetic
#: collection; vocab scales alongside so list count grows too)
SCALING_DOCS = (250, 1000, 4000)
SCALING_PAGE = 512


def bench_scaling(engines=DEFAULT_ENGINES, n_queries=4096) -> list[dict]:
    """Corpus-size sweep: batched next_geq throughput per engine as the
    compressed stream grows past the page size (the regime the paged
    kernel exists for)."""
    rows = []
    for nd in SCALING_DOCS:
        lists, u = corpus_lists(num_docs=nd, vocab_size=2 * nd,
                                mean_doc_len=120)
        res = repair_compress(lists)
        fi = build_flat_index(res)
        pi = build_paged_index(fi, SCALING_PAGE)
        rng = np.random.default_rng(0)
        lids = rng.integers(0, len(lists), n_queries).astype(np.int32)
        xs = rng.integers(0, u, n_queries).astype(np.int32)
        for name in engines:
            kwargs: dict = {}
            if name == "jnp":
                kwargs = dict(fi=fi, paged=True, page_size=SCALING_PAGE)
            elif name == "pallas":
                kwargs = dict(fi=fi, page_size=SCALING_PAGE)
            eng = make_engine(name, res, **kwargs)
            eng.next_geq_batch(lids, xs)     # warmup / jit compile
            t0 = time.perf_counter()
            eng.next_geq_batch(lids, xs)
            dt = time.perf_counter() - t0
            rows.append({
                "num_docs": nd,
                "stream_symbols": int(fi.c.shape[0]),
                "num_pages": pi.num_pages,
                "engine": name,
                "batch": n_queries,
                "next_geq_qps": n_queries / dt,
                "us_per_probe": 1e6 * dt / n_queries,
            })
    emit(rows, f"N-scaling sweep: batched next_geq throughput vs corpus "
               f"size (page={SCALING_PAGE})")
    return rows


def _ratio_buckets(lists, rng, n_pairs):
    lens = np.asarray([len(l) for l in lists])
    buckets = {1: [], 10: [], 100: []}
    tries = 0
    while tries < 20000 and any(len(v) < n_pairs for v in buckets.values()):
        tries += 1
        i, j = rng.integers(0, len(lists), 2)
        if i == j or lens[i] == 0:
            continue
        if lens[i] > lens[j]:
            i, j = j, i
        ratio = lens[j] / max(lens[i], 1)
        for b in buckets:
            if b <= ratio < b * 10 and len(buckets[b]) < n_pairs:
                buckets[b].append((int(i), int(j)))
                break
    return buckets


def bench_engines(res, buckets, engines=DEFAULT_ENGINES) -> list[dict]:
    """Per-engine batched throughput on the same pair stream: one
    ``intersect_pairs`` call per (engine, ratio-bucket), timed after a
    warmup call (device engines jit-compile on first use)."""
    rows = []
    for name in engines:
        # no interpret override: PallasEngine auto-selects (compiled on
        # TPU, interpreter elsewhere), so the axis measures the real tier
        eng = make_engine(name, res)
        for b, pairs in buckets.items():
            if not pairs:
                continue
            if isinstance(eng, DeviceEngine):  # warmup: jit compile at the
                eng.intersect_pairs(pairs)     # timed batch shape

            t0 = time.perf_counter()
            outs = eng.intersect_pairs(pairs)
            dt = time.perf_counter() - t0
            rows.append({
                "engine": name,
                "ratio_bucket": f"{b}-{b*10}",
                "n_pairs": len(pairs),
                "us_per_query": 1e6 * dt / len(pairs),
                "queries_per_s": len(pairs) / dt,
                "result_docs": int(sum(len(o) for o in outs)),
            })
    return rows


def run(n_pairs=60, engines=DEFAULT_ENGINES) -> tuple[list[dict], list[dict]]:
    lists, u = corpus_lists()
    res = repair_compress(lists)
    asamp = build_a_sampling(res, k=8)
    bsamp = build_b_sampling(res, B=8)
    enc = CD.encode_lists(lists, "vbyte", k=8, universe=u)

    rng = np.random.default_rng(0)
    buckets = _ratio_buckets(lists, rng, n_pairs)

    def ops_count(make_acc, pairs):
        """Machine-independent cost (§4): symbol touches per query."""
        total = 0
        for i, j in pairs:
            short = I.CompressedList(res, i).decode()
            acc = make_acc(j)
            I._svs_core(short, acc)
            total += acc.ops
        return total / len(pairs)

    rows = []
    for b, pairs in buckets.items():
        if not pairs:
            continue

        def bench(fn):
            t = 0.0
            for i, j in pairs:
                t += time_us(fn, i, j, repeat=1, number=3)
            return t / len(pairs)

        rows.append({
            "ratio_bucket": f"{b}-{b*10}",
            "n_pairs": len(pairs),
            "merge_us": bench(lambda i, j: I.intersect_merge(lists[i], lists[j])),
            "skip_us": bench(lambda i, j: I.intersect_skip(res, i, j)),
            "svs_exp_us": bench(lambda i, j: I.intersect_svs(res, i, j, asamp, "exp")),
            "lookup_us": bench(lambda i, j: I.intersect_lookup(res, i, j, bsamp)),
            "vbyte_svs_us": bench(lambda i, j: CD.svs_encoded(lists[i], enc, j)),
            "uncomp_svs_us": bench(lambda i, j: I.svs_uncompressed(lists[i], lists[j])),
            "skip_ops": ops_count(lambda j: I.CompressedList(res, j), pairs),
            "svs_ops": ops_count(lambda j: I.SampledList(res, j, asamp, "exp"), pairs),
            "lookup_ops": ops_count(lambda j: I.LookupList(res, j, bsamp), pairs),
        })
    emit(rows, "fig3-left: intersection time by n/m ratio "
               "(us/query wall, ops = symbol touches)")

    engine_rows = bench_engines(res, buckets, engines)
    emit(engine_rows, "engine axis: batched intersect_pairs throughput "
                      "per backend (us/query)")
    return rows, engine_rows


def main(engines=DEFAULT_ENGINES, scaling: bool = True) -> dict:
    validate_engines(engines)  # before the (slow) host-method sweep
    rows, engine_rows = run(engines=engines)
    scaling_rows = bench_scaling(engines) if scaling else []
    # The paper's algorithmic claim, in the machine-independent measure:
    # sampling cuts the symbols touched vs the unsampled skip scan.
    # (Wall-clock merge here is numpy's C loop vs our Python svs loops —
    # cross-language constants, not the paper's comparison; see
    # EXPERIMENTS.md note.)
    hi = [r for r in rows if r["ratio_bucket"] == "100-1000"]
    if hi:
        assert hi[0]["svs_ops"] < hi[0]["skip_ops"]
        assert hi[0]["lookup_ops"] < hi[0]["skip_ops"]
    # machine-readable per-engine throughput (benchmarks/run.py writes this
    # to BENCH_intersection.json)
    return {
        "host_methods": rows,
        "engines": engine_rows,
        "scaling": scaling_rows,
        "throughput_qps": {
            name: float(np.mean([r["queries_per_s"] for r in engine_rows
                                 if r["engine"] == name]))
            for name in {r["engine"] for r in engine_rows}
        },
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", type=str, default=",".join(DEFAULT_ENGINES),
                    help="comma-separated backends: host,jnp,pallas")
    ap.add_argument("--no-scaling", action="store_true",
                    help="skip the corpus-size (N-scaling) sweep")
    args = ap.parse_args()
    main(engines=tuple(args.engine.split(",")), scaling=not args.no_scaling)
