"""Streaming-ingestion benchmark: search under concurrent inserts
(DESIGN.md §12).

A coverage corpus (doc 0 carries the whole vocabulary, so dense list ids
equal global term ids on both sides of every check) is split into a base
build plus an insert stream.  Per ingest rate r we interleave ``r``
``insert()`` calls with a fixed boolean + ranked query batch per round and
report qps, p50/p95 latency, and the segment-tier telemetry (flushes,
flush milliseconds, compactions, live segments).  Rate 0 is the static
baseline the ingesting cells are read against.

Honest-numbers notes:

* every timed configuration is first replayed on a fresh server with all
  answers oracle-checked (``naive_eval`` / ``rank_oracle`` — exact docs
  AND scores), so a qps number can never come from a wrong answer;
* flush and compaction stalls are INSIDE the timed window — inserts are
  timed end to end, so the delta-budget flushes and background merges the
  stream triggers show up in qps/p95 instead of being hidden between
  measurements (``flush_ms`` tells you how much of the wall went there).

  PYTHONPATH=src python -m benchmarks.run --only ingest
  PYTHONPATH=src python -m benchmarks.bench_ingest --engine host
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.build import make_builder
from repro.data.pipeline import PostingsSource
from repro.engine import validate_engines
from repro.query import naive_eval
from repro.query.parser import parse
from repro.query.topk import rank_oracle
from repro.serve.query_serve import QueryServer

from .common import BENCH_SEED, emit

DEFAULT_ENGINES = ("host", "jnp")
INGEST_RATES = (0, 2, 8)

VOCAB = 128
BASE_DOCS = 96
ROUNDS = 6
QUERIES_PER_ROUND = 8
TOPK = 10
DELTA_BUDGET = int(os.environ.get("REPRO_DELTA_BUDGET", "12"))


def _docs(n_extra):
    src = PostingsSource(base_docs=BASE_DOCS, growth_docs=32, vocab=VOCAB,
                         mean_doc_len=20, seed=BENCH_SEED + 7)
    return [np.arange(VOCAB, dtype=np.int64)] + \
        [src.doc_terms(d) for d in range(BASE_DOCS - 1 + n_extra)]


def _invert(docs):
    inv = {}
    for d, terms in enumerate(docs):
        for t in terms.tolist():
            inv.setdefault(int(t), []).append(d)
    return [np.asarray(inv[t], np.int64) for t in sorted(inv)]


def _round_queries(rng):
    """A round's query batch: boolean strings + one ranked term bag."""
    qs = []
    for _ in range(QUERIES_PER_ROUND - 2):
        a, b, c = (int(t) for t in rng.choice(VOCAB, 3, replace=False))
        qs.append(f"{a} AND {b}" if rng.random() < 0.5
                  else f"({a} AND {b}) OR NOT {c}")
    qs.append(f"{int(rng.integers(VOCAB))} AND {int(rng.integers(VOCAB))}")
    ts = sorted(int(t) for t in rng.choice(VOCAB, 4, replace=False))
    return qs, ts


def _server(engine, res):
    kw = dict(max_short_len=64)
    if engine != "host":
        kw.update(paged=True, page_size=128)
    return QueryServer(res, engine=engine, **kw)


def _drive(engine, rate, *, check):
    """One full interleaved run; returns (rows aggregate, telemetry).
    With ``check`` every answer is verified against the oracle over the
    exact current corpus (the differential gate, per round)."""
    docs = _docs(ROUNDS * rate)
    base = docs[:BASE_DOCS]
    srv = _server(engine, make_builder("host").build_grammar(_invert(base)))
    srv.enable_ingest(delta_budget=DELTA_BUDGET, compact_fanout=2)
    rng = np.random.default_rng(BENCH_SEED + 13)
    lat = []
    n_queries = 0
    t_start = time.perf_counter()
    for r in range(ROUNDS):
        for d in docs[BASE_DOCS + r * rate:BASE_DOCS + (r + 1) * rate]:
            srv.insert(d)           # flush/compaction stalls land here
        qs, ts = _round_queries(rng)
        t0 = time.perf_counter()
        outs = srv.search_many(qs)
        rr = srv.search_topk(ts, TOPK)
        lat.append((time.perf_counter() - t0) * 1e3)
        n_queries += len(qs) + 1
        if check:
            cur = docs[:BASE_DOCS + (r + 1) * rate]
            lists, n = _invert(cur), len(cur)
            for q, got in zip(qs, outs):
                np.testing.assert_array_equal(
                    got, naive_eval(parse(q, None), lists, n))
            od, osc = rank_oracle(lists, n, ts, TOPK)
            np.testing.assert_array_equal(rr.docs, od)
            np.testing.assert_array_equal(rr.scores, osc)
    wall = time.perf_counter() - t_start
    lat = np.asarray(lat)
    st = srv.serve_stats()
    return {
        "qps": n_queries / wall,
        "p50_ms": float(np.percentile(lat, 50)),
        "p95_ms": float(np.percentile(lat, 95)),
        "wall_s": wall,
        "n_queries": n_queries,
    }, {k: st[k] for k in ("segments", "delta_docs", "ingested_docs",
                           "flushes", "flush_ms", "compactions")}


def run(engines=DEFAULT_ENGINES) -> list[dict]:
    rows = []
    for name in engines:
        for rate in INGEST_RATES:
            _drive(name, rate, check=True)        # the correctness gate
            timing, tele = _drive(name, rate, check=False)
            rows.append({"engine": name, "ingest_rate": rate,
                         **timing, **tele})
            emit(rows[-1:], f"{name} × ingest rate {rate}")
    return rows


def main(engines=DEFAULT_ENGINES) -> dict:
    validate_engines(engines)
    rows = run(engines)
    return {
        "seed": BENCH_SEED,
        "corpus": dict(vocab=VOCAB, base_docs=BASE_DOCS, rounds=ROUNDS,
                       queries_per_round=QUERIES_PER_ROUND,
                       delta_budget=DELTA_BUDGET),
        "ingest_rates": list(INGEST_RATES),
        "rows": rows,
        "qps": {f"{r['engine']}/r{r['ingest_rate']}": r["qps"]
                for r in rows},
        "p95_ms": {f"{r['engine']}/r{r['ingest_rate']}": r["p95_ms"]
                   for r in rows},
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", type=str, default=",".join(DEFAULT_ENGINES))
    args = ap.parse_args()
    main(engines=tuple(args.engine.split(",")))
