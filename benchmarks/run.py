"""Benchmark orchestrator: one module per paper table/figure.

  python -m benchmarks.run            # all benches
  python -m benchmarks.run --only fig2,heights
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = {
    "fig2": "benchmarks.bench_compression",
    "heights": "benchmarks.bench_heights",
    "fig3": "benchmarks.bench_intersection",
    "fig4": "benchmarks.bench_tradeoff",
    "hybrid": "benchmarks.bench_bitmap_hybrid",
    "optimize": "benchmarks.bench_optimize",
    "roofline": "benchmarks.roofline",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(BENCHES))
    failures = 0
    for name in names:
        mod_name = BENCHES[name]
        print(f"\n{'='*70}\n== {name}  ({mod_name})\n{'='*70}")
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"[{name}] ok in {time.perf_counter()-t0:.1f}s")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"[{name}] FAILED")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
