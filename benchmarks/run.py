"""Benchmark orchestrator: one module per paper table/figure.

  python -m benchmarks.run            # all benches
  python -m benchmarks.run --only fig2,heights

A bench whose ``main()`` returns a JSON-serializable dict gets it written
to ``BENCH_<module-suffix>.json`` (e.g. ``benchmarks.bench_intersection``
-> ``BENCH_intersection.json`` with per-engine throughput) so the perf
trajectory is machine-readable across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCHES = {
    "fig2": "benchmarks.bench_compression",
    "build": "benchmarks.bench_build",
    "heights": "benchmarks.bench_heights",
    "fig3": "benchmarks.bench_intersection",
    "boolean": "benchmarks.bench_boolean",
    "serve": "benchmarks.bench_serve",
    "topk": "benchmarks.bench_topk",
    "tradeoff": "benchmarks.bench_tradeoff",
    "fig4": "benchmarks.bench_tradeoff",     # legacy alias for tradeoff
    "hybrid": "benchmarks.bench_bitmap_hybrid",
    "optimize": "benchmarks.bench_optimize",
    "outofcore": "benchmarks.bench_outofcore",
    "ingest": "benchmarks.bench_ingest",
    "roofline": "benchmarks.roofline",
}


def _json_path(mod_name: str, out_dir: str) -> str:
    suffix = mod_name.rsplit(".", 1)[-1].removeprefix("bench_")
    return os.path.join(out_dir, f"BENCH_{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--json-dir", type=str, default=".",
                    help="where BENCH_*.json reports are written")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(BENCHES))
    failures = 0
    seen: set[str] = set()      # aliases map to one module; run it once
    for name in names:
        mod_name = BENCHES[name]
        if mod_name in seen:
            continue
        seen.add(mod_name)
        print(f"\n{'='*70}\n== {name}  ({mod_name})\n{'='*70}")
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            payload = mod.main()
            if isinstance(payload, dict):
                path = _json_path(mod_name, args.json_dir)
                with open(path, "w") as f:
                    json.dump(payload, f, indent=2, sort_keys=True)
                print(f"[{name}] wrote {path}")
            print(f"[{name}] ok in {time.perf_counter()-t0:.1f}s")
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"[{name}] FAILED")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
