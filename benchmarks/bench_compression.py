"""Paper Fig. 2 + §5.1: compression ratio vs list length, real (Zipf,
topic-correlated) vs randomized lists, Re-Pair vs the gap codecs.

Reproduces the paper's claims:
  * compressed size is NON-monotonic in list length (long lists compress
    better per element),
  * random lists compress WORSE than real ones (paper: 64.24 vs 48.24 MB,
    ~25% penalty — correlation is a real but secondary source),
  * Re-Pair beats byte codes on space (paper: 13% better).
"""

from __future__ import annotations

import numpy as np

from repro.core import codecs as CD
from repro.core.dictionary import build_forest
from repro.core.optimize import optimize_rules
from repro.core.repair import repair_compress
from repro.index.corpus import randomize_lists

from .common import corpus_lists, emit


def total_bits_repair(lists) -> tuple[float, object]:
    res = repair_compress(lists)
    res, _ = optimize_rules(res)
    forest = build_forest(res.grammar)
    return float(forest.size_bits(res.seq.size)), res


def run(num_docs=2000, vocab=5000) -> dict:
    lists, u = corpus_lists(num_docs=num_docs, vocab_size=vocab)
    n_post = sum(len(l) for l in lists)

    rp_bits, res = total_bits_repair(lists)
    rnd = randomize_lists(lists, u, seed=1)
    rp_rand_bits, _ = total_bits_repair(rnd)

    vb = CD.encode_lists(lists, "vbyte", universe=u).size_bits(False)
    rice = CD.encode_lists(lists, "rice", universe=u).size_bits(False)
    gamma = CD.encode_lists(lists, "gamma", universe=u).size_bits(False)
    plain = n_post * int(np.ceil(np.log2(u)))

    rows = [{
        "method": m, "bits": b, "bits_per_posting": b / n_post,
        "vs_plain": b / plain,
    } for m, b in [("repair", rp_bits), ("repair_random", rp_rand_bits),
                   ("vbyte", vb), ("rice", rice), ("gamma", gamma),
                   ("plain", plain)]]
    emit(rows, "fig2: space by method (real vs randomized lists)")

    # Fig 2 left: compressed size vs original length (non-monotonicity)
    by_len = []
    for i in range(res.num_lists):
        by_len.append({"orig_len": int(res.orig_lengths[i]),
                       "compressed_syms": res.compressed_length(i)})
    by_len.sort(key=lambda r: r["orig_len"])
    # report deciles to keep the output small
    dec = [by_len[int(q * (len(by_len) - 1))]
           for q in np.linspace(0, 1, 11)]
    emit(dec, "fig2-left: compressed symbols vs list length (deciles)")

    checks = {
        "random_worse_than_real": bool(rp_rand_bits > rp_bits),
        "repair_beats_vbyte": bool(rp_bits < vb),
        "random_penalty_pct": 100.0 * (rp_rand_bits / rp_bits - 1.0),
        "repair_vs_vbyte_pct": 100.0 * (1.0 - rp_bits / vb),
    }
    emit([checks], "paper-claim checks (§5.1 / §5.2.1)")
    return checks


def main() -> None:
    checks = run()
    assert checks["random_worse_than_real"], "paper claim 2 failed"
    assert checks["repair_beats_vbyte"], "paper claim 1 failed"


if __name__ == "__main__":
    main()
