"""Paper Fig. 2 + §5.1: compression ratio vs list length, real (Zipf,
topic-correlated) vs randomized lists, Re-Pair vs the gap codecs.

Reproduces the paper's claims:
  * compressed size is NON-monotonic in list length (long lists compress
    better per element),
  * random lists compress WORSE than real ones (paper: 64.24 vs 48.24 MB,
    ~25% penalty — correlation is a real but secondary source),
  * Re-Pair beats byte codes on space (paper: 13% better).
"""

from __future__ import annotations

import time

import numpy as np

from repro.build import make_builder, validate_builders
from repro.core import codecs as CD
from repro.core.dictionary import build_forest
from repro.core.optimize import optimize_rules
from repro.index.corpus import randomize_lists

from .common import BENCH_SEED, corpus_lists, emit


def total_bits_repair(lists, builder="host") -> tuple[float, object]:
    res = make_builder(builder).build_grammar(lists)
    res, _ = optimize_rules(res)
    forest = build_forest(res.grammar)
    return float(forest.size_bits(res.seq.size)), res


def build_sweep(builders=("host", "jnp"), sizes=(250, 500, 1000, 2000),
                vocab=5000, table_cap=0, pairs_per_round=64) -> dict:
    """Construction-throughput sweep: corpus size x builder backend.

    Reports STEADY-STATE build time — every builder runs the corpus
    twice and the second run is timed, so device numbers are the
    refresh-workload rate (jit caches warm, the regime
    ``QueryServer.rebuild`` lives in) and host numbers are unchanged by
    the convention.  Records input symbols/sec (the gap-stream length
    the round loop chews through) and rules/sec, per builder per size,
    plus the device speedup over host at the largest point.  All
    backends produce bit-identical grammars, so rule counts cross-check
    the parity gate while we time.
    """
    validate_builders(builders)
    rows = []
    per_size_rules: dict[int, int] = {}
    for nd in sizes:
        lists, _ = corpus_lists(num_docs=nd, vocab_size=vocab)
        n_sym = sum(len(l) for l in lists)
        for name in builders:
            bld = make_builder(name, table_cap=table_cap,
                               pairs_per_round=pairs_per_round)
            bld.build_grammar(lists)         # warm (trace + compile)
            t0 = time.perf_counter()
            res = bld.build_grammar(lists)
            dt = time.perf_counter() - t0
            if per_size_rules.setdefault(nd, res.grammar.num_rules) \
                    != res.grammar.num_rules:
                raise AssertionError(
                    f"builder {name} diverged at num_docs={nd}")
            rows.append({
                "num_docs": nd, "builder": name, "input_symbols": n_sym,
                "rules": res.grammar.num_rules, "build_s": dt,
                "symbols_per_s": n_sym / dt,
                "rules_per_s": res.grammar.num_rules / dt,
            })
    emit(rows, "construction throughput (symbols/sec by builder)")
    largest = max(sizes)
    by = {r["builder"]: r for r in rows if r["num_docs"] == largest}
    host_t = by.get("host", {}).get("build_s")
    speedups = {f"{n}_speedup_vs_host": host_t / r["build_s"]
                for n, r in by.items() if n != "host" and host_t}
    return {"seed": BENCH_SEED, "table_cap": table_cap,
            "pairs_per_round": pairs_per_round, "sweep": rows,
            "largest_num_docs": largest, **speedups}


def run(num_docs=2000, vocab=5000, builder="host") -> dict:
    lists, u = corpus_lists(num_docs=num_docs, vocab_size=vocab)
    n_post = sum(len(l) for l in lists)

    rp_bits, res = total_bits_repair(lists, builder)
    rnd = randomize_lists(lists, u, seed=1)
    rp_rand_bits, _ = total_bits_repair(rnd, builder)

    vb = CD.encode_lists(lists, "vbyte", universe=u).size_bits(False)
    rice = CD.encode_lists(lists, "rice", universe=u).size_bits(False)
    gamma = CD.encode_lists(lists, "gamma", universe=u).size_bits(False)
    plain = n_post * int(np.ceil(np.log2(u)))

    rows = [{
        "method": m, "bits": b, "bits_per_posting": b / n_post,
        "vs_plain": b / plain,
    } for m, b in [("repair", rp_bits), ("repair_random", rp_rand_bits),
                   ("vbyte", vb), ("rice", rice), ("gamma", gamma),
                   ("plain", plain)]]
    emit(rows, "fig2: space by method (real vs randomized lists)")

    # Fig 2 left: compressed size vs original length (non-monotonicity)
    by_len = []
    for i in range(res.num_lists):
        by_len.append({"orig_len": int(res.orig_lengths[i]),
                       "compressed_syms": res.compressed_length(i)})
    by_len.sort(key=lambda r: r["orig_len"])
    # report deciles to keep the output small
    dec = [by_len[int(q * (len(by_len) - 1))]
           for q in np.linspace(0, 1, 11)]
    emit(dec, "fig2-left: compressed symbols vs list length (deciles)")

    checks = {
        "random_worse_than_real": bool(rp_rand_bits > rp_bits),
        "repair_beats_vbyte": bool(rp_bits < vb),
        "random_penalty_pct": 100.0 * (rp_rand_bits / rp_bits - 1.0),
        "repair_vs_vbyte_pct": 100.0 * (1.0 - rp_bits / vb),
    }
    emit([checks], "paper-claim checks (§5.1 / §5.2.1)")
    return checks


def main(builder: str = "host") -> None:
    checks = run(builder=builder)
    assert checks["random_worse_than_real"], "paper claim 2 failed"
    assert checks["repair_beats_vbyte"], "paper claim 1 failed"


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--builder", choices=("host", "jnp", "pallas"),
                    default="host",
                    help="construction backend for the Re-Pair rows")
    args = ap.parse_args()
    main(builder=args.builder)
