"""Time/space tradeoff, two axes (DESIGN.md §10.1):

* fig4-left — the paper's sweep: vary sampling density for Re-Pair
  (a)/(b) and byte codes; report (bits/posting, us/query) pairs for
  runs with 100 <= n/m <= 200 (the paper's window).
* codec axis — force the per-list codec tier to each mode in
  {repair, ef, bitmap, adaptive} and run the SAME Zipf boolean workload
  through the coalescing scheduler on the host engine; report
  (bits/posting, us/query) per mode.  Every query is oracle-checked
  against ``naive_eval`` on a warmup pass before timing, so a timing can
  never come from a wrong answer.  The acceptance headline: adaptive
  must Pareto-dominate or match all-Re-Pair on (bits, time) — the
  space side is structural (the selector refuses bits-inflating picks),
  and ``main()`` asserts it from the measured rows.

  PYTHONPATH=src python -m benchmarks.run --only tradeoff
  PYTHONPATH=src python -m benchmarks.bench_tradeoff
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import codecs as CD
from repro.core import intersect as I
from repro.core.dictionary import build_forest
from repro.core.repair import repair_compress
from repro.core.sampling import build_a_sampling, build_b_sampling
from repro.engine import make_engine
from repro.index.codec_tier import MODES, CodecTier
from repro.query import naive_eval
from repro.serve.scheduler import QueryScheduler

from .common import BENCH_SEED, boolean_workload, corpus_lists, emit, time_us

#: queries per codec mode on the scheduler path (oracle-checked first)
N_CODEC_QUERIES = 48


def run_fig4() -> list[dict]:
    lists, u = corpus_lists()
    n_post = sum(len(l) for l in lists)
    res = repair_compress(lists)
    base_bits = build_forest(res.grammar).size_bits(res.seq.size)

    rng = np.random.default_rng(1)
    lens = np.asarray([len(l) for l in lists])
    pairs = []
    tries = 0
    while len(pairs) < 40 and tries < 40000:
        tries += 1
        i, j = rng.integers(0, len(lists), 2)
        if i == j:
            continue
        if lens[i] > lens[j]:
            i, j = j, i
        if 50 <= lens[j] / max(lens[i], 1) <= 400:
            pairs.append((int(i), int(j)))

    def bench(fn):
        return float(np.mean([time_us(fn, i, j, repeat=1, number=3)
                              for i, j in pairs]))

    rows = []
    comp_lens = np.asarray([res.compressed_length(i)
                            for i in range(res.num_lists)])
    for k in (4, 16, 64):
        asamp = build_a_sampling(res, k=k)
        bits = base_bits + asamp.size_bits(u)
        rows.append({
            "method": f"repair_svs_a{k}",
            "bits_per_posting": bits / n_post,
            "us_per_query": bench(
                lambda i, j: I.intersect_svs(res, i, j, asamp, "exp")),
        })
    for B in (4, 16, 64):
        bsamp = build_b_sampling(res, B=B)
        bits = base_bits + bsamp.size_bits(u, comp_lens)
        rows.append({
            "method": f"repair_lookup_B{B}",
            "bits_per_posting": bits / n_post,
            "us_per_query": bench(
                lambda i, j: I.intersect_lookup(res, i, j, bsamp)),
        })
    for k in (4, 16, 64):
        enc = CD.encode_lists(lists, "vbyte", k=k, universe=u)
        rows.append({
            "method": f"vbyte_svs_k{k}",
            "bits_per_posting": enc.size_bits() / n_post,
            "us_per_query": bench(lambda i, j: CD.svs_encoded(lists[i], enc, j)),
        })
    for k in (4, 16, 64):
        enc = CD.encode_lists(lists, "rice", k=k, universe=u)
        rows.append({
            "method": f"rice_svs_k{k}",
            "bits_per_posting": enc.size_bits() / n_post,
            "us_per_query": bench(lambda i, j: CD.svs_encoded(lists[i], enc, j)),
        })
    emit(rows, "fig4-left: time-space tradeoff (100<=n/m<=200 window)")
    return rows


def run_codecs(n_queries: int = N_CODEC_QUERIES) -> list[dict]:
    lists, _ = corpus_lists()
    res = repair_compress(lists)
    queries = boolean_workload(len(lists), [len(l) for l in lists],
                               n_queries=n_queries)
    oracle = [naive_eval(q, lists, res.universe) for q in queries]

    rows = []
    for mode in MODES:
        eng = make_engine("host", res, codec=mode)
        tier = eng.tier or CodecTier(
            mode="repair", codec=np.zeros(res.num_lists, np.int8),
            ef=None, bm=None, universe=res.universe)
        rep = tier.space_report(res)
        # warmup + oracle gate before the timed pass
        warm = QueryScheduler(eng, batch_window=8, result_cache_size=0)
        for got, want in zip(warm.search_many(queries), oracle):
            np.testing.assert_array_equal(got, want)
        sch = QueryScheduler(eng, batch_window=8, result_cache_size=0)
        t0 = time.perf_counter()
        sch.search_many(queries)
        dt = time.perf_counter() - t0
        counts = tier.counts()
        rows.append({
            "codec": mode,
            "bits_per_posting": rep["bits_per_posting"],
            "us_per_query": 1e6 * dt / len(queries),
            "qps": len(queries) / dt,
            "n_queries": len(queries),
            "n_repair": counts["repair"],
            "n_ef": counts["ef"],
            "n_bitmap": counts["bitmap"],
        })
        emit(rows[-1:], f"codec={mode}")
        # per-codec round telemetry (warmup + timed), for the record
        rows[-1]["dispatches"] = dict(eng.codec_dispatches)
    return rows


def main() -> dict:
    fig4 = run_fig4()
    # Re-Pair variants use less space than the matching vbyte density
    rp = min(r["bits_per_posting"] for r in fig4 if r["method"].startswith("repair"))
    vb = min(r["bits_per_posting"] for r in fig4 if r["method"].startswith("vbyte"))
    assert rp < vb

    codec = run_codecs()
    by = {r["codec"]: r for r in codec}
    # adaptive never inflates space over all-Re-Pair (Pareto guard)
    assert by["adaptive"]["bits_per_posting"] <= by["repair"]["bits_per_posting"]
    return {
        "seed": BENCH_SEED,
        "rows": fig4,
        "codec_rows": codec,
        "bits_per_posting": {r["codec"]: r["bits_per_posting"] for r in codec},
        "us_per_query": {r["codec"]: r["us_per_query"] for r in codec},
    }


if __name__ == "__main__":
    main()
