"""Paper Fig. 4 (left): time/space tradeoff — vary sampling density for
Re-Pair (a)/(b) and byte codes; report (bits/posting, us/query) pairs for
runs with 100 <= n/m <= 200 (the paper's window)."""

from __future__ import annotations

import numpy as np

from repro.core import codecs as CD
from repro.core import intersect as I
from repro.core.dictionary import build_forest
from repro.core.repair import repair_compress
from repro.core.sampling import build_a_sampling, build_b_sampling

from .common import corpus_lists, emit, time_us


def run() -> list[dict]:
    lists, u = corpus_lists()
    n_post = sum(len(l) for l in lists)
    res = repair_compress(lists)
    base_bits = build_forest(res.grammar).size_bits(res.seq.size)

    rng = np.random.default_rng(1)
    lens = np.asarray([len(l) for l in lists])
    pairs = []
    tries = 0
    while len(pairs) < 40 and tries < 40000:
        tries += 1
        i, j = rng.integers(0, len(lists), 2)
        if i == j:
            continue
        if lens[i] > lens[j]:
            i, j = j, i
        if 50 <= lens[j] / max(lens[i], 1) <= 400:
            pairs.append((int(i), int(j)))

    def bench(fn):
        return float(np.mean([time_us(fn, i, j, repeat=1, number=3)
                              for i, j in pairs]))

    rows = []
    comp_lens = np.asarray([res.compressed_length(i)
                            for i in range(res.num_lists)])
    for k in (4, 16, 64):
        asamp = build_a_sampling(res, k=k)
        bits = base_bits + asamp.size_bits(u)
        rows.append({
            "method": f"repair_svs_a{k}",
            "bits_per_posting": bits / n_post,
            "us_per_query": bench(
                lambda i, j: I.intersect_svs(res, i, j, asamp, "exp")),
        })
    for B in (4, 16, 64):
        bsamp = build_b_sampling(res, B=B)
        bits = base_bits + bsamp.size_bits(u, comp_lens)
        rows.append({
            "method": f"repair_lookup_B{B}",
            "bits_per_posting": bits / n_post,
            "us_per_query": bench(
                lambda i, j: I.intersect_lookup(res, i, j, bsamp)),
        })
    for k in (4, 16, 64):
        enc = CD.encode_lists(lists, "vbyte", k=k, universe=u)
        rows.append({
            "method": f"vbyte_svs_k{k}",
            "bits_per_posting": enc.size_bits() / n_post,
            "us_per_query": bench(lambda i, j: CD.svs_encoded(lists[i], enc, j)),
        })
    for k in (4, 16, 64):
        enc = CD.encode_lists(lists, "rice", k=k, universe=u)
        rows.append({
            "method": f"rice_svs_k{k}",
            "bits_per_posting": enc.size_bits() / n_post,
            "us_per_query": bench(lambda i, j: CD.svs_encoded(lists[i], enc, j)),
        })
    emit(rows, "fig4-left: time-space tradeoff (100<=n/m<=200 window)")
    return rows


def main() -> None:
    rows = run()
    # Re-Pair variants use less space than the matching vbyte density
    rp = min(r["bits_per_posting"] for r in rows if r["method"].startswith("repair"))
    vb = min(r["bits_per_posting"] for r in rows if r["method"].startswith("vbyte"))
    assert rp < vb


if __name__ == "__main__":
    main()
