"""Boolean-query workload: per-algorithm × per-engine throughput
(DESIGN.md §7.4).

A Zipf-distributed boolean/phrase query stream (``common.boolean_workload``)
is planned and executed through ``repro.query.QueryExecutor`` over every
engine backend, once with the cost model free to choose ("planner") and
once per pinned intersection algorithm (merge / svs / bys / meld) — the
§5-style comparison the paper runs across "various list intersection
algorithms", here with the engine tier as a second axis.  Every result is
oracle-checked before timing, so a qps number can never come from a wrong
answer.

  PYTHONPATH=src python -m benchmarks.run --only boolean
  PYTHONPATH=src python -m benchmarks.bench_boolean --engine host,jnp
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.jax_index import build_flat_index
from repro.core.repair import repair_compress
from repro.engine import make_engine, validate_engines
from repro.query import QueryExecutor, naive_eval

from .common import BENCH_SEED, boolean_workload, corpus_lists, emit

DEFAULT_ENGINES = ("host", "jnp", "pallas")
ALGO_AXIS = (None, "merge", "svs", "bys", "meld")   # None = planner's pick

CORPUS = dict(num_docs=600, vocab_size=1500, mean_doc_len=60)


def run(engines=DEFAULT_ENGINES, n_queries=24) -> list[dict]:
    lists, num_docs = corpus_lists(**CORPUS)
    res = repair_compress(lists)
    fi = build_flat_index(res)
    queries = boolean_workload(len(lists), [len(l) for l in lists],
                               n_queries=n_queries)
    oracle = [naive_eval(q, lists, res.universe) for q in queries]

    rows = []
    for name in engines:
        kwargs = {"fi": fi} if name in ("jnp", "pallas") else {}
        eng = make_engine(name, res, **kwargs)
        for algo in ALGO_AXIS:
            qx = QueryExecutor(eng, force_algo=algo)
            plans = [qx.plan(q) for q in queries]
            used = set().union(*(p.algos() for p in plans))
            hits = 0
            for q, p, want in zip(queries, plans, oracle):
                got = qx.run_plan(p)        # warmup (jit) + oracle gate
                np.testing.assert_array_equal(got, want)
                hits += got.size
            t0 = time.perf_counter()
            for p in plans:
                qx.run_plan(p)
            dt = time.perf_counter() - t0
            rows.append({
                "engine": name,
                "algo": algo or "planner",
                "algos_used": ",".join(sorted(used - {"seed"})) or "none",
                "n_queries": len(queries),
                "qps": len(queries) / dt,
                "us_per_query": 1e6 * dt / len(queries),
                "hits": int(hits),
            })
            emit(rows[-1:], f"{name} × {algo or 'planner'}")
    return rows


def main(engines=DEFAULT_ENGINES, n_queries=24) -> dict:
    validate_engines(engines)
    rows = run(engines, n_queries)
    return {
        "seed": BENCH_SEED,
        "corpus": CORPUS,
        "rows": rows,
        "qps": {f"{r['engine']}/{r['algo']}": r["qps"] for r in rows},
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", type=str, default=",".join(DEFAULT_ENGINES))
    ap.add_argument("--n", type=int, default=24)
    args = ap.parse_args()
    main(engines=tuple(args.engine.split(",")), n_queries=args.n)
