"""Construction throughput: the build-pipeline twin of bench_intersection.

Sweeps corpus size x builder backend (``repro.build``: host numpy loop vs
the fixed-shape device round pipeline) and records input symbols/sec and
rules/sec into BENCH_build.json via benchmarks/run.py — the perf
trajectory of the construction tier across PRs, plus the device speedup
over host at the largest sweep point (the ISSUE-3 acceptance number).

The pallas builder is included automatically on TPU; on CPU its kernel
runs in interpret mode (a parity harness, not a perf configuration), so
it is opt-in via REPRO_BENCH_PALLAS=1.

Standalone:

  PYTHONPATH=src python -m benchmarks.bench_build --builders host,jnp
"""

from __future__ import annotations

import os

import jax

from .bench_compression import build_sweep


def main(builders=None, sizes=(250, 500, 1000, 2000)) -> dict:
    if builders is None:
        builders = ["host", "jnp"]
        if (jax.default_backend() == "tpu"
                or os.environ.get("REPRO_BENCH_PALLAS")):
            builders.append("pallas")
    # a finite table cap keeps every backend on the identical [CN07]
    # capped-counting configuration the parity gate covers (and is what
    # bounds the pallas candidate table on real corpora)
    return build_sweep(builders=tuple(builders), sizes=tuple(sizes),
                       table_cap=4096)


if __name__ == "__main__":
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--builders", type=str, default=None,
                    help="comma list from {host,jnp,pallas}")
    ap.add_argument("--sizes", type=str, default="250,500,1000,2000")
    args = ap.parse_args()
    payload = main(
        builders=args.builders.split(",") if args.builders else None,
        sizes=tuple(int(s) for s in args.sizes.split(",")))
    print(json.dumps(payload, indent=2, sort_keys=True))
