"""Serving-throughput benchmark: the cross-query batching runtime
(DESIGN.md §8.4) and the hot-path dedup layer on top (DESIGN.md §13).

A Zipf boolean/phrase workload (``common.boolean_workload``) is driven
through the coalescing scheduler at concurrency {1, 8, 64} per engine
backend, each cell twice: with cross-query lane dedup + the probe memo
ON (the default serving configuration) and OFF (the PR 5 dispatch-every-
lane path).  Concurrency 1 is the serial baseline (batch window 1 — one
query in flight, coalescing factor exactly 1); higher windows let the
scheduler merge the pending probe rounds of all in-flight queries into
shared device dispatches.  Reported per cell: qps, p50/p95 latency, the
mean coalescing factor, and the lane ledger — ``real_lanes`` (what the
queries asked for), ``unique_lanes`` (what survived dedup),
``pad_lanes`` (pow2 filler; reported separately so no factor counts
padding as work), plus ``dedup_factor`` and ``memo_hit_rate``.

Every result is oracle-checked on a warmup pass before timing, so a qps
number can never come from a wrong answer.  The warmup also populates
the probe memo in the ON cells — deliberately: the memo's steady state
for hot Zipf terms is exactly what serving measures.  Honest-numbers
note (same as BENCH_build): on a 2-core CPU box the host engine wins on
raw qps — the device engines pay interpreter/XLA dispatch costs that
batching amortizes but cannot erase; the coalescing factor and lane
ledger are the hardware-portable signal (on a real accelerator each
merged dispatch is one kernel launch, and every deduped lane is device
work that never happens).

  PYTHONPATH=src python -m benchmarks.run --only serve
  PYTHONPATH=src python -m benchmarks.bench_serve --engine host,jnp
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.cache import LRUCache
from repro.core.jax_index import build_flat_index
from repro.core.repair import repair_compress
from repro.engine import make_engine, validate_engines
from repro.query import naive_eval
from repro.serve.scheduler import QueryScheduler

from .common import BENCH_SEED, boolean_workload, corpus_lists, emit

DEFAULT_ENGINES = ("host", "jnp", "pallas")
CONCURRENCY = (1, 8, 64)

CORPUS = dict(num_docs=600, vocab_size=1500, mean_doc_len=60)


def run(engines=DEFAULT_ENGINES, n_queries=64) -> list[dict]:
    lists, num_docs = corpus_lists(**CORPUS)
    res = repair_compress(lists)
    fi = build_flat_index(res)
    queries = boolean_workload(len(lists), [len(l) for l in lists],
                               n_queries=n_queries)
    oracle = [naive_eval(q, lists, res.universe) for q in queries]

    rows = []
    for name in engines:
        kwargs = {"fi": fi} if name in ("jnp", "pallas") else {}
        for dedup_on in (True, False):
            eng = make_engine(name, res, **kwargs)
            if not dedup_on:
                eng.dedup = False
                eng._probe_memo = LRUCache(0)
            for conc in CONCURRENCY:
                # warmup pass: jit compilation + the correctness gate
                # (+ memo steady state in the ON cells)
                warm = QueryScheduler(eng, batch_window=conc,
                                      result_cache_size=0)
                for got, want in zip(warm.search_many(queries), oracle):
                    np.testing.assert_array_equal(got, want)
                # timed pass on a fresh scheduler (result cache off: we
                # are timing execution, not memoization of whole results)
                sch = QueryScheduler(eng, batch_window=conc,
                                     result_cache_size=0)
                t0 = time.perf_counter()
                sch.search_many(queries)
                dt = time.perf_counter() - t0
                st = sch.stats()
                rows.append({
                    "engine": name,
                    "concurrency": conc,
                    "dedup": dedup_on,
                    "n_queries": len(queries),
                    "qps": len(queries) / dt,
                    "p50_ms": st["p50_ms"],
                    "p95_ms": st["p95_ms"],
                    "coalescing_factor": st["coalescing_factor"],
                    "dispatches": st["dispatches"],
                    "merged_lanes": st["merged_lanes"],
                    "real_lanes": st["real_lanes"],
                    "unique_lanes": st["unique_lanes"],
                    "pad_lanes": st["pad_lanes"],
                    "dispatched_lanes": st["dispatched_lanes"],
                    "dedup_factor": st["dedup_factor"],
                    "memo_hit_rate": st["memo_hit_rate"],
                })
                emit(rows[-1:], f"{name} × concurrency {conc} × "
                                f"dedup={'on' if dedup_on else 'off'}")
    return rows


def main(engines=DEFAULT_ENGINES, n_queries=64) -> dict:
    validate_engines(engines)
    rows = run(engines, n_queries)
    qps = {f"{r['engine']}/c{r['concurrency']}"
           f"/{'on' if r['dedup'] else 'off'}": r["qps"] for r in rows}
    # dedup delta at the widest concurrency: ON qps / OFF qps per engine
    speedup = {}
    for name in engines:
        on = next(r for r in rows if r["engine"] == name
                  and r["concurrency"] == CONCURRENCY[-1] and r["dedup"])
        off = next(r for r in rows if r["engine"] == name
                   and r["concurrency"] == CONCURRENCY[-1]
                   and not r["dedup"])
        speedup[name] = on["qps"] / off["qps"]
        assert on["dedup_factor"] > 1.0, \
            f"{name}: Zipf traffic must dedup ({on['dedup_factor']})"
    assert max(speedup.values()) > 1.0, \
        f"dedup should win somewhere at c{CONCURRENCY[-1]}: {speedup}"
    return {
        "seed": BENCH_SEED,
        "corpus": CORPUS,
        "concurrency": list(CONCURRENCY),
        "rows": rows,
        "qps": qps,
        "coalescing": {f"{r['engine']}/c{r['concurrency']}"
                       f"/{'on' if r['dedup'] else 'off'}":
                       r["coalescing_factor"] for r in rows},
        "dedup_speedup_at_max_conc": speedup,
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", type=str, default=",".join(DEFAULT_ENGINES))
    ap.add_argument("--n", type=int, default=64)
    args = ap.parse_args()
    main(engines=tuple(args.engine.split(",")), n_queries=args.n)
