"""Serving-throughput benchmark: the cross-query batching runtime
(DESIGN.md §8.4).

A Zipf boolean/phrase workload (``common.boolean_workload``) is driven
through the coalescing scheduler at concurrency {1, 8, 64} per engine
backend.  Concurrency 1 is the serial baseline (batch window 1 — one
query in flight, coalescing factor exactly 1); higher windows let the
scheduler merge the pending probe rounds of all in-flight queries into
shared device dispatches.  Reported per cell: qps, p50/p95 latency, and
the mean coalescing factor (queries per merged dispatch — the direct
measure of amortized dispatch overhead).

Every result is oracle-checked on a warmup pass before timing, so a qps
number can never come from a wrong answer.  Honest-numbers note (same as
BENCH_build): on a 2-core CPU box the host engine wins on raw qps — the
device engines pay interpreter/XLA dispatch costs that batching amortizes
but cannot erase; the coalescing factor column is the hardware-portable
signal (it rises with concurrency on every backend, and on a real
accelerator each merged dispatch is one kernel launch instead of many).

  PYTHONPATH=src python -m benchmarks.run --only serve
  PYTHONPATH=src python -m benchmarks.bench_serve --engine host,jnp
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.jax_index import build_flat_index
from repro.core.repair import repair_compress
from repro.engine import make_engine, validate_engines
from repro.query import naive_eval
from repro.serve.scheduler import QueryScheduler

from .common import BENCH_SEED, boolean_workload, corpus_lists, emit

DEFAULT_ENGINES = ("host", "jnp", "pallas")
CONCURRENCY = (1, 8, 64)

CORPUS = dict(num_docs=600, vocab_size=1500, mean_doc_len=60)


def run(engines=DEFAULT_ENGINES, n_queries=64) -> list[dict]:
    lists, num_docs = corpus_lists(**CORPUS)
    res = repair_compress(lists)
    fi = build_flat_index(res)
    queries = boolean_workload(len(lists), [len(l) for l in lists],
                               n_queries=n_queries)
    oracle = [naive_eval(q, lists, res.universe) for q in queries]

    rows = []
    for name in engines:
        kwargs = {"fi": fi} if name in ("jnp", "pallas") else {}
        eng = make_engine(name, res, **kwargs)
        for conc in CONCURRENCY:
            # warmup pass: jit compilation + the correctness gate
            warm = QueryScheduler(eng, batch_window=conc,
                                  result_cache_size=0)
            for got, want in zip(warm.search_many(queries), oracle):
                np.testing.assert_array_equal(got, want)
            # timed pass on a fresh scheduler (result cache off: we are
            # timing execution, not memoization)
            sch = QueryScheduler(eng, batch_window=conc,
                                 result_cache_size=0)
            t0 = time.perf_counter()
            sch.search_many(queries)
            dt = time.perf_counter() - t0
            st = sch.stats()
            rows.append({
                "engine": name,
                "concurrency": conc,
                "n_queries": len(queries),
                "qps": len(queries) / dt,
                "p50_ms": st["p50_ms"],
                "p95_ms": st["p95_ms"],
                "coalescing_factor": st["coalescing_factor"],
                "dispatches": st["dispatches"],
                "merged_lanes": st["merged_lanes"],
            })
            emit(rows[-1:], f"{name} × concurrency {conc}")
    return rows


def main(engines=DEFAULT_ENGINES, n_queries=64) -> dict:
    validate_engines(engines)
    rows = run(engines, n_queries)
    return {
        "seed": BENCH_SEED,
        "corpus": CORPUS,
        "concurrency": list(CONCURRENCY),
        "rows": rows,
        "qps": {f"{r['engine']}/c{r['concurrency']}": r["qps"]
                for r in rows},
        "coalescing": {f"{r['engine']}/c{r['concurrency']}":
                       r["coalescing_factor"] for r in rows},
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", type=str, default=",".join(DEFAULT_ENGINES))
    ap.add_argument("--n", type=int, default=64)
    args = ap.parse_args()
    main(engines=tuple(args.engine.split(",")), n_queries=args.n)
