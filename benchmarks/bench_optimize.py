"""Paper §3.4: dictionary-size optimization — bits at every cut point,
the chosen optimum, and construction-speed of the [CN07] approximation."""

from __future__ import annotations

import time

import numpy as np

from repro.core.optimize import optimize_rules, predict_sizes
from repro.core.repair import repair_compress

from .common import corpus_lists, emit


def run() -> dict:
    lists, u = corpus_lists()

    t0 = time.perf_counter()
    exact_small = repair_compress(lists[:80], exact=True)
    t_exact = time.perf_counter() - t0
    t0 = time.perf_counter()
    approx_small = repair_compress(lists[:80], pairs_per_round=64)
    t_approx = time.perf_counter() - t0

    res = repair_compress(lists)
    sizes = predict_sizes(res)
    opt, report = optimize_rules(res)

    idx = np.linspace(0, res.grammar.num_rules, 9).astype(int)
    rows = [{"cut_rules": int(i), "predicted_bits": int(sizes[i])}
            for i in idx]
    emit(rows, "sec3.4: predicted total bits at rule-cut points")
    summary = {
        "total_rules": res.grammar.num_rules,
        "best_rules": report.best_num_rules,
        "orig_bits": report.orig_bits,
        "best_bits": report.best_bits,
        "saving_pct": 100.0 * (1 - report.best_bits / report.orig_bits),
        "exact_build_s_80lists": t_exact,
        "approx_build_s_80lists": t_approx,
        "approx_speedup": t_exact / max(t_approx, 1e-9),
    }
    emit([summary], "sec3.4 summary + [CN07] construction speed")
    return summary


def main() -> None:
    s = run()
    assert s["best_bits"] <= s["orig_bits"]
    assert s["approx_build_s_80lists"] <= s["exact_build_s_80lists"] * 1.2


if __name__ == "__main__":
    main()
